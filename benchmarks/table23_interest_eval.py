"""Tables 2 & 3 analogue: per-day interest evaluation for Football / Location.

Per day: total removed/added triples, interesting removed/added, potentially
interesting dataset size, elapsed seconds — the exact columns of the paper's
Tables 2/3, on the scaled synthetic stream.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import IrapEngine

from .common import (
    FOOTBALL,
    LOCATION,
    csv_row,
    default_generator,
    football_caps,
    location_caps,
    save_json,
)


def _run_interest(name, expr, caps, init_filter, n_days, per_day, scale):
    gen = default_generator(seed=2, scale=scale)
    gen.initial_dump()
    engine = IrapEngine(gen.dict)
    init = gen.slice_for(init_filter)
    sub = engine.register_interest(expr, caps, initial_target=init)

    rows: List[dict] = []
    total_eval_s = 0.0
    n_cs = 0
    for day in range(n_days):
        tot_rm = tot_ad = int_rm = int_ad = 0
        t_day = 0.0
        for _ in range(per_day):
            d_np, a_np = gen.changeset()
            t0 = time.perf_counter()
            out = sub.apply(d_np, a_np)
            dt = time.perf_counter() - t0
            t_day += dt
            total_eval_s += dt
            n_cs += 1
            tot_rm += int(d_np.shape[0])
            tot_ad += int(a_np.shape[0])
            int_rm += int(out.r.n)
            int_ad += int(out.a.n)
        rows.append(
            {
                "day": day + 1,
                "total_removed": tot_rm,
                "interesting_removed": int_rm,
                "total_added": tot_ad,
                "interesting_added": int_ad,
                "potentially_interesting": int(sub.rho.n),
                "elapsed_s": round(t_day, 3),
            }
        )
    tot_rm = sum(r["total_removed"] for r in rows)
    tot_ad = sum(r["total_added"] for r in rows)
    sel_rm = sum(r["interesting_removed"] for r in rows) / max(tot_rm, 1)
    sel_ad = sum(r["interesting_added"] for r in rows) / max(tot_ad, 1)
    payload = {
        "interest": name,
        "rows": rows,
        "selectivity_removed": sel_rm,
        "selectivity_added": sel_ad,
        "target_size": int(sub.tau.n),
        "initial_target_size": int(init.shape[0]),
        "avg_eval_s_per_changeset": total_eval_s / max(n_cs, 1),
        "paper_reference": {
            "football": {"removed_pct": 0.38, "added_pct": 0.335,
                         "avg_eval_s": 0.87},
            "location": {"removed_pct": 4.38, "added_pct": 1.81,
                         "avg_eval_s": 5.31},
        }[name],
    }
    save_json(f"table_{name}", payload)
    us = 1e6 * total_eval_s / max(n_cs, 1)
    derived = (
        f"sel_rm={sel_rm:.4f};sel_ad={sel_ad:.4f};"
        f"rho={int(sub.rho.n)};tau={int(sub.tau.n)}"
    )
    return csv_row(f"table2_{name}" if name == "football" else f"table3_{name}", us, derived)


def run_football(n_days=5, per_day=3, scale=1.0) -> str:
    return _run_interest(
        "football",
        FOOTBALL,
        football_caps(scale),
        lambda t: t[0].startswith(("dbr:Athlete", "dbr:Team")),
        n_days,
        per_day,
        scale,
    )


def run_location(n_days=5, per_day=3, scale=1.0) -> str:
    return _run_interest(
        "location",
        LOCATION,
        location_caps(scale),
        lambda t: True,  # paper: Location target starts as the FULL dump
        n_days,
        per_day,
        scale,
    )
