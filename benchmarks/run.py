# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one entry per paper table/figure (+ kernel micro).

    PYTHONPATH=src python -m benchmarks.run [--scale 1.0] [--days 5]

Outputs ``name,us_per_call,derived`` CSV rows on stdout and one JSON per
benchmark under experiments/bench/.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--days", type=int, default=5)
    ap.add_argument("--per-day", type=int, default=3)
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from . import broker_churn, broker_fanout, broker_flush
    from . import broker_journal, broker_scaling, broker_shard
    from . import fig4_growth, kernels_micro
    from . import table1_changesets
    from . import table23_interest_eval as t23

    benches = {
        "table1": lambda: table1_changesets.run(args.days, args.per_day, args.scale),
        "table2_football": lambda: t23.run_football(args.days, args.per_day, args.scale),
        "table3_location": lambda: t23.run_location(args.days, args.per_day, args.scale),
        "fig4_growth": lambda: fig4_growth.run(args.days, args.per_day, args.scale),
        "kernel_triple_match": kernels_micro.run_triple_match,
        "kernel_merge_probe": kernels_micro.run_merge_probe,
        "broker_scaling": lambda: broker_scaling.run(args.scale),
        "broker_churn": lambda: broker_churn.run(args.scale),
        "broker_flush": lambda: broker_flush.run(args.scale),
        "broker_fanout": lambda: broker_fanout.run(args.scale),
        "broker_shard": lambda: broker_shard.run(args.scale),
        "broker_journal": lambda: broker_journal.run(args.scale),
    }
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        try:
            print(fn(), flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name},NaN,ERROR:{e!r}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
