"""Table 1 analogue: distribution of published changesets over the stream."""
from __future__ import annotations

import time

import numpy as np

from .common import csv_row, default_generator, save_json


def run(n_days: int = 5, per_day: int = 3, scale: float = 1.0) -> str:
    gen = default_generator(seed=1, scale=scale)
    gen.initial_dump()
    days = []
    t0 = time.perf_counter()
    n_cs = 0
    for _ in range(n_days):
        tot_rm = tot_ad = 0
        for _ in range(per_day):
            d_np, a_np = gen.changeset()
            tot_rm += int(d_np.shape[0])
            tot_ad += int(a_np.shape[0])
            n_cs += 1
    # re-derive per-day table deterministically for the record
        days.append({"removed": tot_rm, "added": tot_ad, "changesets": per_day})
    elapsed = time.perf_counter() - t0
    payload = {
        "days": days,
        "total_changesets": n_cs,
        "initial_triples": len(gen.current),
        "elapsed_s": elapsed,
    }
    save_json("table1_changesets", payload)
    us = 1e6 * elapsed / max(n_cs, 1)
    return csv_row(
        "table1_changesets",
        us,
        f"days={n_days};changesets={n_cs};initial_triples={len(gen.current)}",
    )
