"""Sharded vs single-device broker flush throughput on an 8-device mesh.

Drives identical deferred workloads — ``n_subs`` subscribers over several
shape cohorts, half flushed early so every full flush drains TWO distinct
consumption frontiers — through three brokers:

  * single  — no mesh (the PR 3 device-resident broker),
  * placed  — ``Broker(mesh=...)``: cohorts placed on mesh devices
              (``CohortPlacement`` round-robin), frontier passes dispatched
              grouped by device so cohorts run concurrently,
  * sharded — ``Broker(mesh=..., shard_cohorts=True)``: every cohort pass
              inside shard_map (hash-partitioned τ shards, all_to_all-routed
              probes, block-gather-stitched bank words).

Before timing, one warm round asserts all three paths' flush outputs
bit-identical to each other AND to eager evaluation of the same composed
batches by the seed per-interest engine. Reported: flush seconds per round
(compile time excluded via ``BrokerStats.rejit_s``), cohort passes per
device (``Broker.device_passes``), and sharded/placed vs single speedups.
Emits ``experiments/bench/BENCH_shard.json``.

The forced host-device mesh requires ``XLA_FLAGS`` before jax initializes,
so the measurement runs in a child process
(``--xla_force_host_platform_device_count=8``); on a CPU host mesh the
collectives are emulated and the sharded path's value is architectural
(memory scale-out + the routing overhead trend), not raw speed — the
recorded ratio quantifies exactly that overhead.

    PYTHONPATH=src python -m benchmarks.run --only shard
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

N_DEVICES = 8
_MARK = "BENCH_SHARD_JSON:"


def _child(scale: float, n_subs: int, n_rounds: int, per_round: int) -> None:
    from repro.core import (
        Broker,
        CohortPlacement,
        Dictionary,
        IrapEngine,
        PushPolicy,
    )
    from repro.core.distributed import make_mesh_compat

    from benchmarks.broker_flush import (
        _assert_outputs_equal,
        _caps,
        _composed,
        _interest,
        _stream,
    )

    mesh = make_mesh_compat((N_DEVICES,), ("shard",))

    def build(name: str):
        d = Dictionary()
        stream = _stream(d, 2 * per_round * (n_rounds + 1), seed=0)
        if name == "single":
            broker = Broker(d)
        elif name == "placed":
            broker = Broker(
                d, mesh=mesh, placement=CohortPlacement(mode="round_robin")
            )
        else:
            broker = Broker(d, mesh=mesh, shard_cohorts=True)
        policy = PushPolicy.max_staleness(1e9)  # only explicit flush fires
        subs = [
            broker.subscribe(_interest(i), _caps(), policy=policy)
            for i in range(n_subs)
        ]
        return broker, subs, stream

    brokers = {name: build(name) for name in ("single", "placed", "sharded")}

    # -- warm + parity round: all paths vs eager composed-batch evaluation
    flushed = {}
    for name, (broker, subs, stream) in brokers.items():
        for cs in stream[: 2 * per_round]:
            broker.process_changeset(*cs)
        flushed[name] = broker.flush()
    d_ref = Dictionary()
    ref_stream = _stream(d_ref, 2 * per_round, seed=0)
    engine = IrapEngine(d_ref)
    refs = [
        engine.register_interest(_interest(i), _caps())
        for i in range(n_subs)
    ]
    d_np, a_np = _composed(ref_stream)
    for k, ref in enumerate(refs):
        want = ref.apply(d_np, a_np)
        for name in brokers:
            _assert_outputs_equal(flushed[name][k], want, f"{name}/{k}")

    # -- timed rounds (steady state: executables, statics, τ shards cached)
    results = {}
    for name, (broker, subs, stream) in brokers.items():
        half = subs[: len(subs) // 2]
        it = iter(stream[2 * per_round :])
        warm_stats = len(broker.stats)
        passes_before = dict(broker.device_passes)
        for _ in range(n_rounds):
            for _ in range(per_round):
                broker.process_changeset(*next(it))
            broker.flush(subs=half)
            for _ in range(per_round):
                broker.process_changeset(*next(it))
            broker.flush()
        flush_stats = [
            st for st in broker.stats[warm_stats:] if st.total_added == 0
        ]
        eval_s = sum(st.elapsed_s - st.rejit_s for st in flush_stats)
        results[name] = {
            "n_flushes": len(flush_stats),
            "flush_eval_s": eval_s,
            "flush_eval_s_per_round": eval_s / max(1, n_rounds),
            "cohort_passes": sum(st.n_cohort_passes for st in flush_stats),
            "rejit_s": sum(st.rejit_s for st in broker.stats[warm_stats:]),
            "device_passes": {
                str(dev): n - passes_before.get(dev, 0)
                for dev, n in sorted(broker.device_passes.items())
            },
            "n_subscribers": n_subs,
            "changesets_per_round": 2 * per_round,
        }

    single_s = results["single"]["flush_eval_s"]
    payload = {
        "n_devices": N_DEVICES,
        "single_device": results["single"],
        "placed": results["placed"],
        "sharded": results["sharded"],
        "sharded_vs_single_speedup": single_s
        / max(1e-9, results["sharded"]["flush_eval_s"]),
        "placed_vs_single_speedup": single_s
        / max(1e-9, results["placed"]["flush_eval_s"]),
        "parity": {
            "bit_identical_to_single_device": True,
            "checked_against_eager_composed_batches": True,
            "subscribers_checked": n_subs,
        },
        "scale": scale,
    }
    print(_MARK + json.dumps(payload), flush=True)


def run(scale: float = 1.0, n_subs: int = 12, n_rounds: int = 4,
        per_round: int = 3) -> str:
    from .common import csv_row, save_json

    env = dict(os.environ)
    # overwrite rather than append: with repeated flags XLA honors the last
    # occurrence, so an inherited --xla_force_host_platform_device_count
    # (e.g. the CI mesh-test step's =4) would override the 8-device mesh
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES}"
    )
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = (
        src + os.pathsep
        + os.path.dirname(os.path.dirname(__file__))
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [
            sys.executable, "-m", "benchmarks.broker_shard", "--child",
            str(scale), str(n_subs), str(n_rounds), str(per_round),
        ],
        capture_output=True,
        text=True,
        env=env,
        timeout=3600,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"broker_shard child failed:\n{proc.stdout[-2000:]}"
            f"\n{proc.stderr[-2000:]}"
        )
    line = next(
        ln for ln in proc.stdout.splitlines() if ln.startswith(_MARK)
    )
    payload = json.loads(line[len(_MARK):])
    save_json("BENCH_shard", payload)
    us = payload["sharded"]["flush_eval_s_per_round"] * 1e6
    return csv_row(
        "broker_shard",
        us,
        f"shard_x={payload['sharded_vs_single_speedup']:.2f};"
        f"placed_x={payload['placed_vs_single_speedup']:.2f};"
        f"devs={N_DEVICES};subs={payload['sharded']['n_subscribers']}",
    )


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        _child(
            float(sys.argv[2]), int(sys.argv[3]),
            int(sys.argv[4]), int(sys.argv[5]),
        )
    else:
        print(run())
