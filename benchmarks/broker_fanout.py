"""Subsumption-lattice fanout: flush cost tracks distinct interests,
not subscriber count.

The lattice layer (``core/interest.py`` + ``core/broker.py``) makes a
flush evaluate each *distinct canonical interest* once and fan the result
out to every subscriber holding it. This benchmark drives the claim with
a fixed 64-expression interest pool built as 16 containment families x 4
syntactic variants:

  * parent        ``(?a p_f ?v)(?v q_f ?w)``        — a real bank row pair
  * child         ``(e0 p_f ?v)(?v q_f ?w)``        — constant subject:
                  canonically distinct, but its bound pattern rides a
                  *virtual* lane refined from the parent's row
                  (``kernels.ops.lane_refine``)
  * renamed       parent with fresh variable names   — canonical duplicate
  * reordered     parent with patterns swapped       — canonical duplicate

Canonicalization collapses the 64 expressions to 32 distinct interests
(16 parents + 16 children), half of whose bank lanes are virtual. The
subscriber draw covers the pool round-robin first (so every distinct
interest is resident at every sweep size) and Zipf-samples the rest —
heavy skew, as real subscriber populations concentrate on few interests.

Two sweeps are reported:

  * subscribers 32 -> 10k over the fixed pool: distinct interests — and
    therefore cohort slots — are constant, so flush time should be
    near-flat (the acceptance line: <= 1.5x growth end to end) while
    ``fanout_copies`` grows 312x,
  * distinct interests 8 -> 32 at fixed subscribers: flush time should
    scale with the distinct count — the cost unit the lattice reduces
    delivery to.

Before timing, a parity block runs lattice-on and lattice-off brokers
plus the seed per-interest oracle (``IrapEngine`` on the *original*,
un-canonicalized expressions) over the same changesets and asserts all
three bit-identical per subscriber. Emits
``experiments/bench/BENCH_fanout.json``.

    PYTHONPATH=src python -m benchmarks.run --only fanout
"""
from __future__ import annotations

import gc
import time
from collections import OrderedDict
from typing import List, Tuple

import numpy as np

from repro.core import (
    Broker,
    Dictionary,
    InterestExpr,
    IrapEngine,
    PushPolicy,
    StepCapacities,
)

from .common import csv_row, save_json

N_FAMILIES = 16  # containment families in the full pool
N_ENTITIES = 64
N_OBJECTS = 16
ZIPF_S = 1.3  # subscriber skew over the pool


def _pool(n_families: int = N_FAMILIES) -> List[InterestExpr]:
    """4 * n_families expressions, 2 * n_families distinct canonical forms.

    Parents and children interleave first so any prefix covers the same
    parent:child mix (the resident base at the smallest sweep size already
    holds every distinct interest); the pure duplicates come last.
    """
    first, dups = [], []
    for f in range(n_families):
        p, q = f"p{f}", f"q{f}"
        first.append(
            InterestExpr.parse(
                "synthetic://fanout", f"local://fam{f}",
                bgp=[("?a", p, "?v"), ("?v", q, "?w")],
            )
        )
        first.append(
            InterestExpr.parse(
                "synthetic://fanout", f"local://fam{f}",
                bgp=[("e0", p, "?v"), ("?v", q, "?w")],
            )
        )
        dups.append(
            InterestExpr.parse(
                "synthetic://fanout", f"local://fam{f}",
                bgp=[("?x", p, "?y"), ("?y", q, "?z")],
            )
        )
        dups.append(
            InterestExpr.parse(
                "synthetic://fanout", f"local://fam{f}",
                bgp=[("?v", q, "?w"), ("?a", p, "?v")],
            )
        )
    return first + dups


def _caps() -> StepCapacities:
    return StepCapacities(
        n_removed=1024, n_added=128, tau=512, rho=128, pulls=64, fanout=2
    )


def _dict() -> Dictionary:
    d = Dictionary()
    for f in range(N_FAMILIES):
        d.encode_term(f"p{f}")
        d.encode_term(f"q{f}")
    for i in range(N_ENTITIES):
        d.encode_term(f"e{i}")
    for i in range(N_OBJECTS):
        d.encode_term(f"o{i}")
    return d


def _stream(
    d: Dictionary, n: int, d_rows: int = 256, a_rows: int = 32, seed: int = 0
) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)

    def rows(k):
        out = []
        for _ in range(k):
            e = f"e{rng.integers(N_ENTITIES)}"
            if rng.random() < 0.5:
                out.append((e, f"p{rng.integers(N_FAMILIES)}",
                            f"e{rng.integers(N_ENTITIES)}"))
            else:
                out.append((e, f"q{rng.integers(N_FAMILIES)}",
                            f"o{rng.integers(N_OBJECTS)}"))
        return d.encode_triples(out)

    return [(rows(d_rows), rows(a_rows)) for _ in range(n)]


def _draw(n_subs: int, pool_size: int, rng) -> List[int]:
    # resident base: cover the pool round-robin so the distinct-interest
    # set is identical at every sweep size; everyone after that is a
    # Zipf-skewed repeat — pure fanout over already-resident lane groups
    base = [i % pool_size for i in range(min(n_subs, pool_size))]
    extra = (rng.zipf(ZIPF_S, size=max(0, n_subs - pool_size)) - 1) % pool_size
    return base + list(extra)


def _assert_outputs_equal(got, want, label):
    for field in ("r", "r_i", "r_prime", "a", "a_i"):
        gf, wf = getattr(got, field), getattr(want, field)
        if int(gf.n) != int(wf.n) or not np.array_equal(
            np.asarray(gf.spo), np.asarray(wf.spo)
        ):
            raise AssertionError(f"lattice outputs diverge: {label}/{field}")


def _parity(n_changesets: int = 3) -> int:
    """Lattice-on == lattice-off == seed oracle, per subscriber per flush.

    Runs a reduced pool (4 families: 8 distinct interests, 12 subscribers
    including one renamed and one reordered duplicate pair) so the seed
    oracle stays cheap, but covers every variant kind the full pool uses:
    canonical joins, virtual child lanes, and plain fanout.
    """
    pool = _pool(4)
    picks = list(range(8)) + [8, 9, 10, 11]  # parents+children, then dups
    caps = _caps()
    policy = PushPolicy.max_staleness(1e9)

    d_on, d_off, d_ref = _dict(), _dict(), _dict()
    b_on = Broker(d_on, subsume_interests=True)
    b_off = Broker(d_off, subsume_interests=False)
    subs_on = [b_on.subscribe(pool[i], caps, policy=policy) for i in picks]
    subs_off = [b_off.subscribe(pool[i], caps, policy=policy) for i in picks]
    engine = IrapEngine(d_ref)
    refs = [engine.register_interest(pool[i], caps) for i in picks]

    stream_on = _stream(d_on, n_changesets, seed=11)
    stream_off = _stream(d_off, n_changesets, seed=11)
    stream_ref = _stream(d_ref, n_changesets, seed=11)
    for ci in range(n_changesets):
        b_on.process_changeset(*stream_on[ci])
        b_off.process_changeset(*stream_off[ci])
        outs_on = b_on.flush()
        outs_off = b_off.flush()
        for k, ref in enumerate(refs):
            want = ref.apply(*stream_ref[ci])
            _assert_outputs_equal(outs_on[k], want, f"on/{k}/cs{ci}")
            _assert_outputs_equal(outs_off[k], want, f"off/{k}/cs{ci}")
    assert b_on.stats[-1].distinct_interests == 8
    assert b_off.stats[-1].distinct_interests == 12
    assert b_on.stats[-1].fanout_copies == 12
    return len(picks)


def _measure(
    n_subs: int,
    n_families: int,
    exec_cache,
    n_rounds: int,
    k_per_flush: int = 4,
    n_warm: int = 3,
) -> dict:
    d = _dict()
    pool = _pool(n_families)
    rng = np.random.default_rng(1)
    broker = Broker(d, subsume_interests=True)
    broker._exec_cache = exec_cache  # identical shapes across sweep points
    policy = PushPolicy.max_staleness(1e9)
    for i in _draw(n_subs, len(pool), rng):
        broker.subscribe(pool[i], _caps(), policy=policy)
    stream = _stream(d, (n_rounds + n_warm) * k_per_flush)
    it = iter(stream)
    for _ in range(n_warm):
        for _ in range(k_per_flush):
            broker.process_changeset(*next(it))
        broker.flush()
    n0 = len(broker.stats)
    # timed rounds: GC parked so a collection doesn't land inside one
    # flush of one sweep point and skew the endpoint ratio
    gc.collect()
    gc.disable()
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        for _ in range(k_per_flush):
            broker.process_changeset(*next(it))
        broker.flush()
    wall_s = (time.perf_counter() - t0) / n_rounds
    gc.enable()
    fires = [s for s in broker.stats[n0:] if s.n_evaluated > 0]
    fire_s = sum(s.elapsed_s - s.rejit_s for s in fires) / len(fires)
    last = fires[-1]
    bank = broker.bank
    return {
        "n_subscribers": n_subs,
        "pool_exprs": len(pool),
        "distinct_interests": last.distinct_interests,
        "fanout_copies": last.fanout_copies,
        "flush_fire_s": fire_s,
        "round_wall_s": wall_s,
        "bank_real_rows": bank.n_real,
        "bank_virtual_rows": bank.n_virtual,
        "bank_words": bank.n_words,
        "rejit_s": sum(s.rejit_s for s in broker.stats[n0:]),
    }


def run(scale: float = 1.0, n_rounds: int = 6) -> str:
    n_max = max(320, int(round(10000 * scale)))
    sizes = tuple(sorted({32, 320, 3200, n_max}))

    subscribers_checked = _parity()

    # one executable cache across sweep points: every point runs the same
    # cohort shapes (that is the point — distinct interests are constant)
    cache: "OrderedDict[tuple, object]" = OrderedDict()
    sweep = [_measure(n, N_FAMILIES, cache, n_rounds) for n in sizes]
    base, top = sweep[0], sweep[-1]
    growth = top["flush_fire_s"] / base["flush_fire_s"]

    # distinct-interest scaling at fixed fanout: fresh cache per pool size
    # (cohort shapes differ), subscribers held at the mid sweep point
    by_distinct = [
        _measure(3200, nf, OrderedDict(), max(3, n_rounds // 2))
        for nf in (4, 8, 16)
    ]

    save_json(
        "BENCH_fanout",
        {
            "pool": {
                "n_exprs": 4 * N_FAMILIES,
                "n_families": N_FAMILIES,
                "n_distinct_canonical": 2 * N_FAMILIES,
                "zipf_s": ZIPF_S,
            },
            "subscriber_sweep": sweep,
            "flush_growth_32_to_max": growth,
            "fanout_growth_32_to_max": (
                top["fanout_copies"] / base["fanout_copies"]
            ),
            "distinct_sweep": by_distinct,
            "parity": {
                "lattice_on_vs_off_vs_seed_oracle": True,
                "subscribers_checked": subscribers_checked,
            },
            "scale": scale,
        },
    )
    us = top["flush_fire_s"] * 1e6
    return csv_row(
        "broker_fanout",
        us,
        f"growth_32_to_{top['n_subscribers']}={growth:.2f}x;"
        f"distinct={top['distinct_interests']};"
        f"fanout={top['fanout_copies']}",
    )
