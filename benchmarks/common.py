"""Shared benchmark scaffolding: paper interest expressions + scaled setup.

The synthetic stream is a scaled-down DBpedia Live (paper §4): the full 2014
dump (365M triples, 12k changesets over 15 days) does not fit a CPU-only
container, so sizes scale down ~1000x while keeping the paper's *structure*:
mixed-domain dump, two interests (Football: 4-pattern BGP with an
object-subject join; Location: 5-pattern subject-star BGP + 1 OGP), and
changesets dominated by uninteresting churn. Reported metrics are counts,
selectivities (compare to the paper's 0.3-4.4%), and elapsed seconds.
"""
from __future__ import annotations

import dataclasses
import json
import subprocess
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.core import InterestExpr, IrapEngine, StepCapacities
from repro.data import DBpediaLikeGenerator, GeneratorConfig

EXP_DIR = Path(__file__).resolve().parents[1] / "experiments" / "bench"
_REPO_DIR = Path(__file__).resolve().parents[1]


def bench_meta() -> dict:
    """Provenance stamp for every emitted BENCH_*.json: git sha, jax
    version, and device kind, so the perf trajectory in experiments/bench/
    is attributable to a commit and a machine."""
    import jax

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=_REPO_DIR, capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
        dirty = bool(
            subprocess.run(
                ["git", "status", "--porcelain"],
                cwd=_REPO_DIR, capture_output=True, text=True, timeout=10,
            ).stdout.strip()
        )
    except Exception:
        sha, dirty = None, None
    dev = jax.devices()[0]
    return {
        "git_sha": sha,
        "git_dirty": dirty,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "n_devices": jax.device_count(),
    }

FOOTBALL = InterestExpr.parse(
    source="synthetic://dbpedia-live",
    target="local://football",
    bgp=[
        ("?footballer", "rdf:type", "dbo:SoccerPlayer"),
        ("?footballer", "foaf:name", "?name"),
        ("?footballer", "dbo:team", "?team"),
        ("?team", "rdfs:label", "?teamName"),
    ],
)

LOCATION = InterestExpr.parse(
    source="synthetic://dbpedia-live",
    target="local://location",
    bgp=[
        ("?location", "rdf:type", "?type"),
        ("?location", "wgs:long", "?long"),
        ("?location", "wgs:lat", "?lat"),
        ("?location", "rdfs:label", "?label"),
        ("?location", "dbo:abstract", "?abstract"),
    ],
    ogp=[("?location", "dcterms:subject", "?subject")],
)


def default_generator(seed=0, scale=1.0) -> DBpediaLikeGenerator:
    cfg = GeneratorConfig(
        n_athletes=int(300 * scale),
        n_places=int(500 * scale),
        n_other=int(2500 * scale),
        n_teams=50,
        seed=seed,
        adds_per_changeset=int(500 * scale),
        removes_per_changeset=int(250 * scale),
    )
    return DBpediaLikeGenerator(cfg)


def football_caps(scale=1.0, dedup=2048) -> StepCapacities:
    # dedup=0 reproduces the paper-faithful naive probe pools (§Perf HC-C)
    return StepCapacities(
        n_removed=1024, n_added=2048, tau=1 << 15, rho=1 << 14,
        pulls=1 << 14, fanout=8, dedup_candidates=dedup,
    )


def location_caps(scale=1.0, dedup=4096) -> StepCapacities:
    return StepCapacities(
        n_removed=1024, n_added=2048, tau=1 << 16, rho=1 << 15,
        pulls=1 << 14, fanout=8, dedup_candidates=dedup,
    )


def save_json(name: str, payload) -> None:
    EXP_DIR.mkdir(parents=True, exist_ok=True)
    if isinstance(payload, dict) and "meta" not in payload:
        payload = {**payload, "meta": bench_meta()}
    (EXP_DIR / f"{name}.json").write_text(json.dumps(payload, indent=1))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
