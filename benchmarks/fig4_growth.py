"""Figure 4 analogue: replica growth — iRap vs full live mirror.

Tracks per-day dataset sizes for (a) the interest-based replica τ, (b) the
potentially-interesting store ρ, and (c) a full mirror applying every
changeset verbatim (Def 6) — the paper's headline 'two orders of magnitude'
comparison (Fig 4b) plus ρ growth (Fig 4e).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import IrapEngine, apply_changeset, from_numpy, to_numpy

from .common import FOOTBALL, csv_row, default_generator, football_caps, save_json


def run(n_days: int = 5, per_day: int = 3, scale: float = 1.0) -> str:
    gen = default_generator(seed=3, scale=scale)
    dump = gen.initial_dump()
    engine = IrapEngine(gen.dict)
    sub = engine.register_interest(
        FOOTBALL,
        football_caps(scale),
        initial_target=gen.slice_for(
            lambda t: t[0].startswith(("dbr:Athlete", "dbr:Team"))
        ),
    )
    mirror = from_numpy(dump, 1 << 17)

    growth = []
    t0 = time.perf_counter()
    for day in range(n_days):
        for _ in range(per_day):
            d_np, a_np = gen.changeset()
            sub.apply(d_np, a_np)
            mirror, ovf = apply_changeset(
                mirror, from_numpy(d_np, 4096), from_numpy(a_np, 4096)
            )
            assert not bool(ovf)
        growth.append(
            {
                "day": day + 1,
                "mirror": int(mirror.n),
                "irap_tau": int(sub.tau.n),
                "irap_rho": int(sub.rho.n),
            }
        )
    elapsed = time.perf_counter() - t0
    ratio = growth[-1]["mirror"] / max(growth[-1]["irap_tau"], 1)
    payload = {"growth": growth, "final_ratio_mirror_over_tau": ratio,
               "elapsed_s": elapsed}
    save_json("fig4_growth", payload)
    us = 1e6 * elapsed / (n_days * per_day)
    return csv_row("fig4_growth", us, f"mirror/tau={ratio:.1f};days={n_days}")
