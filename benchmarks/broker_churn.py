"""Subscription-churn sweep: cohort-cached lifecycle vs PR 1 full rebuilds.

The paper's deployment (§1, §3) is long-lived subscribers that come and go
against a continuously-evolving source. PR 1's broker rebuilt its entire
fused jitted step on every subscribe/unsubscribe, so under churn the system
spent its wall-clock in XLA recompiles, not evaluation. This benchmark
drives the same churn sequence — at ``n_subs`` subscribers, alternately
unsubscribing and re-subscribing interests across several shape cohorts with
changesets flowing throughout — through two brokers:

  * cached   — the cohort executable cache (default): a membership change
               recompiles at most its own cohort, and re-subscription of a
               previously-seen shape/padded-size reuses executables outright,
  * rebuild  — ``Broker(cache_executables=False)``: every membership change
               discards all compiled steps (the PR 1 lifecycle).

Reported: total re-jit seconds (``BrokerStats.rejit_s``) and executable
compile counts over the churn phase, plus steady-state evaluation time.
Emits ``experiments/bench/BENCH_churn.json``.

    PYTHONPATH=src python -m benchmarks.run --only churn
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import Broker, Dictionary, InterestExpr, StepCapacities

from .common import csv_row, save_json

N_SHAPES = 4  # distinct static plan shapes -> distinct cohorts


def _interest(i: int) -> InterestExpr:
    """Interest i: shape family ``i % N_SHAPES``, patterns from a fixed
    predicate pool so re-subscription reuses tombstoned bank lanes."""
    cls = f"cls{i % 8}"
    p = f"p{i % 8}"
    shape = i % N_SHAPES
    if shape == 0:
        bgp = [("?a", "rdf:type", cls), ("?a", p, "?v")]
        ogp = []
    elif shape == 1:
        bgp = [("?a", "rdf:type", cls)]
        ogp = []
    elif shape == 2:
        bgp = [("?a", "rdf:type", cls), ("?a", p, "?v")]
        ogp = [("?a", "foaf:page", "?w")]
    else:
        bgp = [("?x", p, "?a"), ("?a", "rdf:type", cls)]
        ogp = []
    return InterestExpr.parse(
        source="synthetic://churn", target=f"local://sub{i}", bgp=bgp, ogp=ogp
    )


def _caps() -> StepCapacities:
    return StepCapacities(
        n_removed=64, n_added=64, tau=256, rho=128, pulls=64, fanout=4
    )


def _stream(d: Dictionary, n: int, seed: int = 0) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)

    def rows(k):
        out = []
        for _ in range(k):
            e = f"e{rng.integers(0, 200)}"
            kind = rng.integers(0, 4)
            if kind == 0:
                out.append((e, "rdf:type", f"cls{rng.integers(0, 8)}"))
            elif kind == 1:
                out.append((e, f"p{rng.integers(0, 8)}", f"o{rng.integers(0, 30)}"))
            else:
                out.append((e, f"noise{rng.integers(0, 6)}", f"o{rng.integers(0, 30)}"))
        return d.encode_triples(out)

    return [(rows(16), rows(24)) for _ in range(n)]


def _run_churn(
    d: Dictionary, n_subs: int, n_events: int, cache: bool
) -> dict:
    """Warm a broker at ``n_subs`` subscribers, then churn membership."""
    stream = _stream(d, 2 + 2 * n_events)
    broker = Broker(d, cache_executables=cache)
    subs = [broker.subscribe(_interest(i), _caps()) for i in range(n_subs)]
    next_id = n_subs
    # warm phase: compile every cohort once
    broker.process_changeset(*stream[0])
    broker.process_changeset(*stream[1])
    warm_rejits = broker.rejit_count
    warm_stats = len(broker.stats)

    for ev in range(n_events):
        victim = subs.pop(ev % len(subs))
        broker.unsubscribe(victim)
        broker.process_changeset(*stream[2 + 2 * ev])
        subs.append(broker.subscribe(_interest(next_id), _caps()))
        next_id += 1
        broker.process_changeset(*stream[3 + 2 * ev])

    churn_stats = broker.stats[warm_stats:]
    rejit_s = sum(st.rejit_s for st in churn_stats)
    eval_s = sum(st.elapsed_s - st.rejit_s for st in churn_stats)
    return {
        "cache_executables": cache,
        "n_subscribers": n_subs,
        "n_membership_changes": 2 * n_events,
        "warm_compiles": warm_rejits,
        "churn_compiles": broker.rejit_count - warm_rejits,
        "churn_rejit_s": rejit_s,
        "churn_eval_s_per_changeset": eval_s / max(1, len(churn_stats)),
        "bank_lanes": broker.bank.n_lanes,
        "bank_lanes_live": broker.bank.n_live,
    }


def run(scale: float = 1.0, n_subs: int = 32, n_events: int = 4) -> str:
    cached = _run_churn(Dictionary(), n_subs, n_events, cache=True)
    rebuild = _run_churn(Dictionary(), n_subs, n_events, cache=False)
    ratio_s = rebuild["churn_rejit_s"] / max(1e-9, cached["churn_rejit_s"])
    ratio_n = rebuild["churn_compiles"] / max(1, cached["churn_compiles"])
    save_json(
        "BENCH_churn",
        {
            "cached": cached,
            "full_rebuild_baseline": rebuild,
            "rejit_s_ratio": ratio_s,
            "compile_count_ratio": ratio_n,
            "scale": scale,
        },
    )
    return csv_row(
        "broker_churn",
        cached["churn_eval_s_per_changeset"] * 1e6,
        f"rejit_x={ratio_s:.1f};compiles {cached['churn_compiles']}"
        f"vs{rebuild['churn_compiles']};subs={n_subs}",
    )
