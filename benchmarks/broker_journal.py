"""Durability tier cost: WAL journal overhead + snapshot-aided recovery.

The journal (``core/journal.py``) write-ahead-logs every ingest before any
batch sees it, and every committed fire's frontier advances after delivery
— so its cost lands on the broker's hot ingest/fire path. This benchmark
prices that, and the recovery path the journal exists for:

  * **ingest+fire throughput** — the same eager+deferred workload through
    three brokers: ``journal=None`` (baseline), a journal with
    ``fsync=False`` (framing/serialization cost only), and one with
    ``fsync=True`` (the durable default: one fsync per appended record).
    Before timing, a parity round asserts the journaled broker's outputs
    and final τ state bit-identical to the baseline's — the unified
    sequence clock means attaching a journal must not change a single id.
  * **recovery time vs tail length** — ``Broker.recover`` from the full
    journal (no snapshot: replay every record, re-evaluating every fire)
    vs from a snapshot taken at ~¾ of the stream (replay only the tail).
    The gap is what ``Broker.snapshot`` + ``compact_journal`` buy a
    long-running daemon.

Reported: wall seconds per changeset for each journal mode (compile time
excluded via ``BrokerStats.rejit_s``), journal overhead ratios, journal
size on disk, and recovery seconds with/without snapshot. Emits
``experiments/bench/BENCH_journal.json``.

    PYTHONPATH=src python -m benchmarks.run --only journal
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.core import (
    Broker,
    ChangesetJournal,
    Dictionary,
    InterestExpr,
    PushPolicy,
    StepCapacities,
)
from repro.core.triples import to_numpy

from .common import csv_row, save_json

N_POOL = 48


def _interest(i: int) -> InterestExpr:
    return InterestExpr.parse(
        source="synthetic://journal",
        target=f"local://sub{i}",
        bgp=[("?a", "rdf:type", f"cls{i}"), ("?a", f"p{i}", "?v")],
    )


def _caps() -> StepCapacities:
    return StepCapacities(
        n_removed=256, n_added=256, tau=1024, rho=256, pulls=128, fanout=2
    )


def _stream(
    d: Dictionary, n: int, d_rows: int = 24, a_rows: int = 48, seed: int = 0
) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)

    def rows(k):
        out = []
        for _ in range(k):
            e = f"e{rng.integers(0, N_POOL)}"
            kind = rng.integers(0, 3)
            if kind == 0:
                out.append((e, "rdf:type", f"cls{rng.integers(0, 8)}"))
            elif kind == 1:
                out.append((e, f"p{rng.integers(0, 8)}", f"o{rng.integers(0, 9)}"))
            else:
                out.append((e, f"noise{rng.integers(0, 4)}", f"o{rng.integers(0, 9)}"))
        return d.encode_triples(out)

    return [(rows(d_rows), rows(a_rows)) for _ in range(n)]


def _build(journal, n_subs: int):
    d = Dictionary()
    broker = Broker(d, journal=journal)
    for i in range(n_subs):
        # half eager (fire every changeset -> one fire record each), half
        # every-4 (composed windows -> pending batches in the journal replay)
        policy = PushPolicy() if i % 2 == 0 else PushPolicy.every(4)
        broker.subscribe(_interest(i), _caps(), policy=policy)
    return d, broker


def _drive(broker, stream) -> Tuple[float, list]:
    outs = []
    t0 = time.perf_counter()
    n_stats = len(broker.stats)
    for rm, ad in stream:
        outs.append(broker.process_changeset(rm, ad))
    outs.append(broker.flush())
    elapsed = time.perf_counter() - t0
    rejit = sum(st.rejit_s for st in broker.stats[n_stats:])
    return elapsed - rejit, outs


def _assert_parity(got, want, label):
    assert len(got) == len(want), label
    for i, (g, w) in enumerate(zip(got, want)):
        assert len(g) == len(w), (label, i)
        for k, (a, b) in enumerate(zip(g, w)):
            assert (a is None) == (b is None), (label, i, k)
            if a is None:
                continue
            for field in ("r", "r_i", "r_prime", "a", "a_i"):
                if not np.array_equal(
                    np.asarray(getattr(a, field).spo),
                    np.asarray(getattr(b, field).spo),
                ):
                    raise AssertionError(
                        f"journaled outputs diverge: {label}/{i}/{k}/{field}"
                    )


def _dir_bytes(path: Path) -> int:
    return sum(p.stat().st_size for p in Path(path).glob("wal_*.seg"))


def run(scale: float = 1.0, n_subs: int = 6, n_steps: int = 24) -> str:
    n_steps = max(8, int(n_steps * scale))
    tmp = Path(tempfile.mkdtemp(prefix="bench_journal_"))
    try:
        warm = 4
        configs = {
            "off": None,
            "nosync": ChangesetJournal(tmp / "nosync", fsync=False),
            "fsync": ChangesetJournal(tmp / "fsync", fsync=True),
        }
        brokers, streams, outs, times = {}, {}, {}, {}
        for name, journal in configs.items():
            d, broker = _build(journal, n_subs)
            stream = _stream(d, warm + n_steps, seed=0)
            # warm: hit every executable/static cache before timing
            _, warm_outs = _drive(broker, stream[:warm])
            times[name], timed_outs = _drive(broker, stream[warm:])
            outs[name] = warm_outs + timed_outs
            brokers[name] = (d, broker)

        # parity: attaching a journal changes no output and no final state
        for name in ("nosync", "fsync"):
            _assert_parity(outs[name], outs["off"], name)
            for s_j, s_0 in zip(brokers[name][1].subs, brokers["off"][1].subs):
                assert s_j.since == s_0.since
                if not np.array_equal(
                    to_numpy(s_j.tau), to_numpy(s_0.tau)
                ):
                    raise AssertionError(f"final tau diverges: {name}")

        # recovery: full-journal replay vs snapshot + tail replay
        d_j, broker_j = brokers["nosync"]
        journal = broker_j.journal
        journal.sync()
        t0 = time.perf_counter()
        r_full = Broker.recover(
            ChangesetJournal(tmp / "nosync", fsync=False), dictionary=d_j
        )
        recover_full_s = time.perf_counter() - t0
        assert r_full._seq == broker_j._seq

        # snapshot near the head of a fresh tail: keep streaming, snapshot,
        # stream the last quarter, then recover (tail = quarter of the run)
        d2, broker2 = _build(
            ChangesetJournal(tmp / "snap", fsync=False), n_subs
        )
        stream2 = _stream(d2, warm + n_steps, seed=0)
        split = warm + (3 * n_steps) // 4
        _drive(broker2, stream2[:split])
        store = CheckpointStore(tmp / "ckpt")
        broker2.snapshot(store)
        broker2.compact_journal()
        _drive(broker2, stream2[split:])
        broker2.journal.sync()
        t0 = time.perf_counter()
        r_snap = Broker.recover(
            ChangesetJournal(tmp / "snap", fsync=False),
            store,
            dictionary=d2,
        )
        recover_snap_s = time.perf_counter() - t0
        assert r_snap._seq == broker2._seq

        per_cs = {k: v / n_steps for k, v in times.items()}
        overhead = {
            k: per_cs[k] / max(1e-9, per_cs["off"]) for k in ("nosync", "fsync")
        }
        payload = {
            "n_changesets": n_steps,
            "n_subscribers": n_subs,
            "ingest_fire_s_per_changeset": per_cs,
            "journal_overhead_ratio": overhead,
            "journal_bytes": {
                "nosync": _dir_bytes(tmp / "nosync"),
                "fsync": _dir_bytes(tmp / "fsync"),
            },
            "recover_full_replay_s": recover_full_s,
            "recover_snapshot_tail_s": recover_snap_s,
            "recover_snapshot_speedup": recover_full_s
            / max(1e-9, recover_snap_s),
            "parity": {
                "outputs_and_final_state_vs_journal_off": True,
                "recovered_seq_matches": True,
            },
            "scale": scale,
        }
        save_json("BENCH_journal", payload)
        us = per_cs["fsync"] * 1e6
        return csv_row(
            "broker_journal",
            us,
            f"fsync_x={overhead['fsync']:.2f};nosync_x={overhead['nosync']:.2f};"
            f"recover {recover_full_s:.1f}s-full/{recover_snap_s:.1f}s-snap",
        )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
