"""Subscriber-scaling sweep: fused broker vs looped per-interest engine.

The paper's deployment has many applications subscribed to one source; the
seed engine pays one full evaluation pass per subscriber per changeset. This
sweep grows the subscriber count (1 -> 32) over a fixed synthetic stream and
reports per-changeset wall time for

  * looped — :class:`repro.core.IrapEngine` (one jitted step per interest),
  * fused  — :class:`repro.core.Broker` (one consolidated pattern bank, one
    fused jitted pass for all subscribers),

plus the fused/looped speedup and the bank dedup ratio. Emits
``experiments/bench/BENCH_broker.json`` so later PRs can track the
subscriber-scaling trajectory.

    PYTHONPATH=src python -m benchmarks.run --only broker
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import (
    Broker,
    Dictionary,
    InterestExpr,
    IrapEngine,
    StepCapacities,
    to_set,
)

from .common import csv_row, save_json

N_CLASSES = 8  # interests share type patterns mod N_CLASSES -> bank dedup


def _interest(i: int) -> InterestExpr:
    return InterestExpr.parse(
        source="synthetic://broker-sweep",
        target=f"local://subscriber{i}",
        bgp=[
            ("?a", "rdf:type", f"cls{i % N_CLASSES}"),
            ("?a", f"p{i}", "?v"),
        ],
    )


def _caps() -> StepCapacities:
    # the broker's target regime: many subscribers, modest per-subscriber
    # state — per-changeset cost is dominated by per-subscriber dispatch and
    # host-loop overhead, which the fused pass amortizes across all of them
    return StepCapacities(
        n_removed=64, n_added=64, tau=256, rho=128, pulls=64, fanout=4
    )


def _stream(
    d: Dictionary, n_subs: int, n_changesets: int, seed: int = 0
) -> Tuple[np.ndarray, List[Tuple[np.ndarray, np.ndarray]]]:
    """Initial dump + changesets mixing interesting and churn triples."""
    rng = np.random.default_rng(seed)

    def rows(n):
        out = []
        for _ in range(n):
            e = f"e{rng.integers(0, 400)}"
            kind = rng.integers(0, 4)
            if kind == 0:
                out.append((e, "rdf:type", f"cls{rng.integers(0, N_CLASSES)}"))
            elif kind == 1:
                out.append((e, f"p{rng.integers(0, n_subs)}", f"o{rng.integers(0, 40)}"))
            else:  # uninteresting churn dominates, like DBpedia Live
                out.append((e, f"noise{rng.integers(0, 6)}", f"o{rng.integers(0, 40)}"))
        return d.encode_triples(out)

    tau0 = rows(100)
    changesets = [(rows(24), rows(40)) for _ in range(n_changesets)]
    return tau0, changesets


def _bench_fused(d, exprs, tau0, changesets) -> Tuple[float, float, Broker]:
    broker = Broker(d)
    for e in exprs:
        broker.subscribe(e, _caps(), initial_target=tau0)
    broker.process_changeset(*changesets[0])  # compile + warm caches
    n_warm_stats = len(broker.stats)
    t0 = time.perf_counter()
    for d_np, a_np in changesets[1:]:
        broker.process_changeset(d_np, a_np)
    dt = (time.perf_counter() - t0) / (len(changesets) - 1)
    # steady-state throughput: compile/rebuild time (BrokerStats.rejit_s) is
    # accounted separately so re-jits (capacity growth, late cohorts) don't
    # masquerade as evaluation cost
    rejit_s = sum(st.rejit_s for st in broker.stats[n_warm_stats:])
    dt_steady = dt - rejit_s / (len(changesets) - 1)
    return dt, dt_steady, broker


def _bench_looped(d, exprs, tau0, changesets) -> Tuple[float, IrapEngine]:
    engine = IrapEngine(d)
    for e in exprs:
        engine.register_interest(e, _caps(), initial_target=tau0)
    engine.process_changeset(*changesets[0])
    t0 = time.perf_counter()
    for d_np, a_np in changesets[1:]:
        engine.process_changeset(d_np, a_np)
    dt = (time.perf_counter() - t0) / (len(changesets) - 1)
    return dt, engine


def run(scale: float = 1.0, sweep=(1, 2, 4, 8, 16, 32), n_changesets=6) -> str:
    results = []
    for n_subs in sweep:
        exprs = [_interest(i) for i in range(n_subs)]
        d = Dictionary()
        tau0, changesets = _stream(d, n_subs, n_changesets)
        fused_dt, fused_steady_dt, broker = _bench_fused(
            d, exprs, tau0, changesets
        )
        looped_dt, engine = _bench_looped(d, exprs, tau0, changesets)
        # correctness guard: both paths must agree on every replica
        for k in range(n_subs):
            assert to_set(broker.subs[k].tau) == to_set(engine.subs[k].tau), k
            assert to_set(broker.subs[k].rho) == to_set(engine.subs[k].rho), k
        results.append(
            {
                "n_subscribers": n_subs,
                "fused_us_per_changeset": fused_dt * 1e6,
                "fused_steady_us_per_changeset": fused_steady_dt * 1e6,
                "fused_rejit_us_per_changeset": (fused_dt - fused_steady_dt)
                * 1e6,
                "looped_us_per_changeset": looped_dt * 1e6,
                "speedup": looped_dt / fused_dt,
                "speedup_steady": looped_dt / max(1e-12, fused_steady_dt),
                "bank_lanes": broker.bank.n_lanes,
                "bank_lanes_raw": sum(s.plan.n_total for s in broker.subs),
            }
        )
    save_json(
        "BENCH_broker",
        {"sweep": results, "n_changesets": n_changesets, "scale": scale},
    )
    at8 = next((r for r in results if r["n_subscribers"] == 8), results[-1])
    return csv_row(
        "broker_scaling",
        at8["fused_us_per_changeset"],
        f"speedup@{at8['n_subscribers']}={at8['speedup']:.2f}x;"
        f"max_subs={results[-1]['n_subscribers']};"
        f"speedup@{results[-1]['n_subscribers']}={results[-1]['speedup']:.2f}x",
    )
