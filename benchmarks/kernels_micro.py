"""Kernel microbenchmarks: XLA-path wall time + interpret-mode validation.

On CPU the Pallas kernels run in interpret mode (correctness only), so the
timed path is the XLA fallback; the derived column records the interpret-mode
allclose check against the oracle so every benchmark run re-validates the
kernels it ships.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import csv_row, save_json


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run_triple_match(n=1 << 18, n_pat=8) -> str:
    rng = np.random.default_rng(0)
    spo = jnp.asarray(rng.integers(0, 1 << 20, size=(n, 3)), jnp.int32)
    pats = jnp.asarray(rng.integers(-1, 64, size=(n_pat, 3)), jnp.int32)
    f = jax.jit(lambda s, p: ref.pattern_bitmask_ref(s, p))
    dt = _time(f, spo, pats)
    # interpret-mode validation on a slice
    sl = spo[: 1 << 14]
    ok = bool(
        jnp.all(
            ops.pattern_bitmask(sl, pats, use_kernel=True)
            == ref.pattern_bitmask_ref(sl, pats)
        )
    )
    gbs = n * 12 / dt / 1e9
    save_json(
        "kernel_triple_match",
        {"n": n, "n_patterns": n_pat, "s_per_call": dt, "GBps_xla_cpu": gbs,
         "interpret_matches_ref": ok},
    )
    return csv_row(
        "kernel_triple_match", dt * 1e6,
        f"GB/s={gbs:.2f};n={n};pats={n_pat};interpret_ok={ok}",
    )


def run_merge_probe(s=1 << 16, q=1 << 15) -> str:
    rng = np.random.default_rng(1)
    store_rows = np.unique(
        rng.integers(0, 1 << 18, size=(s, 3)).astype(np.int32), axis=0
    )
    pad = np.full((s - store_rows.shape[0], 3), np.iinfo(np.int32).max, np.int32)
    store = jnp.asarray(np.concatenate([store_rows, pad]))
    queries = jnp.asarray(rng.integers(0, 1 << 18, size=(q, 3)), jnp.int32)
    f = jax.jit(lambda st, qq: ref.merge_probe_ref(st, qq))
    dt = _time(f, store, queries)
    i_k, f_k = ops.merge_probe(store[: 1 << 13], queries[:4096], use_kernel=True)
    i_r, f_r = ref.merge_probe_ref(store[: 1 << 13], queries[:4096])
    ok = bool(jnp.all(i_k == i_r) & jnp.all(f_k == f_r))
    mps = q / dt / 1e6
    save_json(
        "kernel_merge_probe",
        {"store": s, "queries": q, "s_per_call": dt,
         "Mprobe_per_s_xla_cpu": mps, "interpret_matches_ref": ok},
    )
    return csv_row(
        "kernel_merge_probe", dt * 1e6,
        f"Mprobe/s={mps:.2f};store={s};q={q};interpret_ok={ok}",
    )
