"""Deferred-path throughput: delta-encoded frontier chains vs the PR 3
stacked pass vs the PR 2 host-round-trip baseline.

The broker's scheduled path is where the paper's batching amortization
lives (``PushPolicy`` — slow consumers absorb k changesets per push), and
its frontiers overlap by construction: each later frontier's composed
batch extends the earlier ones with the newest changesets. This benchmark
drives an overlap-heavy deferred workload — removals drawn from a small
entity pool so every frontier's composed D converges on the same distinct
rows, subscriber groups staggered across ``n_groups`` consumption
frontiers so the full flush drains them all at once — through three
brokers:

  * delta     — ``Broker()`` (default): multi-frontier flushes build the
                delta-encoded frontier chain
                (``propagation.build_frontier_chain``) and match the
                distinct-row union ONCE through the segmented bank pass
                (``kernels.ops.pattern_bitmask_words_segmented``), each
                frontier's words composed by membership masking,
  * stacked   — ``Broker(delta_frontiers=False)``: the PR 3 device-resident
                path, one stacked bank pass per fired frontier (shared
                suffix rows re-matched once per frontier),
  * roundtrip — ``Broker(deferred_device_resident=False)``: the PR 2
                behavior (host round trip + sequential per-frontier passes).

Before timing, one warm round asserts all three paths' flush outputs
bit-identical to each other AND to eager evaluation of the same composed
batches by the seed per-interest engine. Reported: multi-frontier flush
seconds per round (compile time excluded via ``BrokerStats.rejit_s``),
``rows_matched`` vs ``rows_distinct`` (the dedup efficacy the chain
exists for), cohort passes, and the delta-vs-stacked / stacked-vs-roundtrip
speedups. Emits ``experiments/bench/BENCH_flush.json``.

    PYTHONPATH=src python -m benchmarks.run --only flush
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import (
    Broker,
    Dictionary,
    InterestExpr,
    IrapEngine,
    PushPolicy,
    StepCapacities,
)
from repro.core.propagation import ChangesetBatch

from .common import csv_row, save_json

N_POOL = 56  # entity pool: small, so composed D sides overlap heavily


def _interest(i: int) -> InterestExpr:
    # one shape cohort, all-distinct patterns: the bank stays wide (every
    # subscriber adds two lanes) while membership stays shape-homogeneous
    return InterestExpr.parse(
        source="synthetic://flush",
        target=f"local://sub{i}",
        bgp=[("?a", "rdf:type", f"cls{i}"), ("?a", f"p{i}", "?v")],
    )


def _caps() -> StepCapacities:
    # D-heavy: big removed-side capacity (the side the chain dedups),
    # small added/ρ sides, shallow probes
    return StepCapacities(
        n_removed=1024, n_added=128, tau=512, rho=128, pulls=128, fanout=2
    )


def _stream(
    d: Dictionary, n: int, d_rows: int = 96, a_rows: int = 24, seed: int = 0
) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)

    def rows(k):
        out = []
        for _ in range(k):
            e = f"e{rng.integers(0, N_POOL)}"
            kind = rng.integers(0, 3)
            if kind == 0:
                out.append((e, "rdf:type", f"cls{rng.integers(0, 24)}"))
            elif kind == 1:
                out.append((e, f"p{rng.integers(0, 24)}", f"o{rng.integers(0, 8)}"))
            else:
                out.append((e, f"noise{rng.integers(0, 4)}", f"o{rng.integers(0, 8)}"))
        return d.encode_triples(out)

    return [(rows(d_rows), rows(a_rows)) for _ in range(n)]


def _composed(changesets, start_id=1):
    batch = ChangesetBatch.fresh(*changesets[0], start_id)
    for i, cs in enumerate(changesets[1:], start=start_id + 1):
        batch.extend(*cs, i)
    return batch.arrays()


def _assert_outputs_equal(got, want, label):
    for field in ("r", "r_i", "r_prime", "a", "a_i"):
        gf, wf = getattr(got, field), getattr(want, field)
        if not np.array_equal(np.asarray(gf.spo), np.asarray(wf.spo)):
            raise AssertionError(f"deferred outputs diverge: {label}/{field}")


def _build(d: Dictionary, n_subs: int, device: bool, delta: bool):
    broker = Broker(
        d, deferred_device_resident=device, delta_frontiers=delta
    )
    policy = PushPolicy.max_staleness(1e9)  # only explicit flush fires
    subs = [
        broker.subscribe(_interest(i), _caps(), policy=policy)
        for i in range(n_subs)
    ]
    return broker, subs


def _run_rounds(
    broker: Broker, subs: list, stream, n_rounds: int, n_groups: int
) -> dict:
    """Each round staggers the subscriber groups across ``n_groups``
    consumption frontiers (feed one changeset, drain one group, repeat),
    then feeds once more and drains everything — so every full flush
    evaluates ``n_groups`` distinct, heavily overlapping frontiers (every
    subscriber sits at its own group's frontier by then)."""
    groups = [subs[i::n_groups] for i in range(n_groups)]
    it = iter(stream)
    warm_stats = len(broker.stats)
    n_subs = len(subs)
    for _ in range(n_rounds):
        for g in groups:
            broker.process_changeset(*next(it))
            broker.flush(subs=g)
        broker.process_changeset(*next(it))
        broker.flush()
    stats = broker.stats[warm_stats:]
    # the multi-frontier full flushes are where the chain dedups; the
    # single-frontier group drains are identical work on every path
    full = [st for st in stats if st.n_evaluated == n_subs]
    flush_stats = [st for st in stats if st.total_added == 0]
    eval_s = sum(st.elapsed_s - st.rejit_s for st in full)
    return {
        "n_full_flushes": len(full),
        "flush_eval_s": eval_s,
        "flush_eval_s_per_round": eval_s / max(1, n_rounds),
        "all_flush_eval_s": sum(
            st.elapsed_s - st.rejit_s for st in flush_stats
        ),
        "cohort_passes": sum(st.n_cohort_passes for st in full),
        "rows_matched": sum(st.rows_matched for st in full),
        "rows_distinct": sum(st.rows_distinct for st in full),
        "frontiers_per_full_flush": n_groups,
        "rejit_s": sum(st.rejit_s for st in stats),
        # lattice efficacy: cohort slots evaluated vs deliveries fanned out
        # (equal here — every interest is distinct — but surfaced so the
        # counters stay visible on the flush path too; broker_fanout is the
        # collapse-heavy workload)
        "distinct_interests": sum(st.distinct_interests for st in full),
        "fanout_copies": sum(st.fanout_copies for st in full),
    }


def run(scale: float = 1.0, n_subs: int = 12, n_rounds: int = 5,
        n_groups: int = 5) -> str:
    need = (n_groups + 1) * (n_rounds + 3)
    streams = {}
    brokers = {}
    configs = (
        ("delta", True, True),
        ("stacked", True, False),
        ("roundtrip", False, True),
    )
    for name, device, delta in configs:
        d = Dictionary()
        stream = _stream(d, need, seed=0)
        brokers[name] = _build(d, n_subs, device, delta)
        streams[name] = stream

    # -- warm + parity round: all paths vs eager composed-batch evaluation,
    # across a two-frontier stagger (half drained early)
    warm_n = n_groups + 1
    flushed = {}
    for name, (broker, subs) in brokers.items():
        warm = streams[name][:warm_n]
        for cs in warm[: warm_n // 2]:
            broker.process_changeset(*cs)
        broker.flush(subs=subs[: n_subs // 2])
        for cs in warm[warm_n // 2 :]:
            broker.process_changeset(*cs)
        flushed[name] = broker.flush()
    d_ref = Dictionary()
    ref_stream = _stream(d_ref, need, seed=0)
    engine = IrapEngine(d_ref)
    refs = [
        engine.register_interest(_interest(i), _caps())
        for i in range(n_subs)
    ]
    half = warm_n // 2
    comp_early = _composed(ref_stream[:half])
    comp_late = _composed(ref_stream[half:warm_n], start_id=half + 1)
    comp_full = _composed(ref_stream[:warm_n])
    for k, ref in enumerate(refs):
        if k < n_subs // 2:
            ref.apply(*comp_early)
            want = ref.apply(*comp_late)
        else:
            want = ref.apply(*comp_full)
        for name in brokers:
            _assert_outputs_equal(flushed[name][k], want, f"{name}/{k}")

    # -- steady-state warm: one unmeasured round with the SAME frontier
    # stagger as the timed rounds, so round 1 hits every executable,
    # static-array, chain-membership, and bucket-shape cache
    per_round = n_groups + 1
    for name, (broker, subs) in brokers.items():
        _run_rounds(broker, subs, streams[name][warm_n:], 1, n_groups)

    # -- timed rounds (steady state: executables + statics cached)
    results = {}
    for name, (broker, subs) in brokers.items():
        results[name] = _run_rounds(
            brokers[name][0], subs, streams[name][warm_n + per_round:],
            n_rounds, n_groups,
        )
        results[name]["n_subscribers"] = n_subs

    delta_speedup = results["stacked"]["flush_eval_s"] / max(
        1e-9, results["delta"]["flush_eval_s"]
    )
    rt_speedup = results["roundtrip"]["flush_eval_s"] / max(
        1e-9, results["delta"]["flush_eval_s"]
    )
    match_ratio = results["stacked"]["rows_matched"] / max(
        1, results["delta"]["rows_matched"]
    )
    save_json(
        "BENCH_flush",
        {
            "delta_chain": results["delta"],
            "stacked_baseline": results["stacked"],
            "round_trip_baseline": results["roundtrip"],
            "delta_vs_stacked_speedup": delta_speedup,
            "delta_vs_roundtrip_speedup": rt_speedup,
            "matched_rows_ratio_stacked_over_delta": match_ratio,
            "parity": {
                "checked_against_eager_composed_batches": True,
                "subscribers_checked": n_subs,
            },
            "scale": scale,
        },
    )
    us = results["delta"]["flush_eval_s_per_round"] * 1e6
    return csv_row(
        "broker_flush",
        us,
        f"delta_x={delta_speedup:.2f};rt_x={rt_speedup:.2f};rows "
        f"{results['delta']['rows_matched']}"
        f"vs{results['stacked']['rows_matched']};subs={n_subs}",
    )
