"""Deferred-path throughput: device-resident + frontier-stacked flush vs
the PR 2 host-round-trip baseline.

The broker's scheduled path is where the paper's batching amortization
lives (``PushPolicy`` — slow consumers absorb k changesets per push). PR 2
paid a device→host→device round trip per fire and one sequential cohort
pass per frontier; this benchmark drives identical deferred workloads —
``n_subs`` subscribers over several shape cohorts, half flushed early so
every full flush drains TWO distinct consumption frontiers — through

  * device    — ``Broker(deferred_device_resident=True)`` (default): fires
                consume the composed batches' sorted device stores
                (``ChangesetBatch.device_stores`` + ``triples.rehome``) and
                same-shape cohorts stack across frontiers into one
                executable call,
  * roundtrip — ``Broker(deferred_device_resident=False)``: the PR 2
                behavior (``ChangesetBatch.arrays()`` + ``from_array``
                re-upload per fire, sequential per-frontier passes).

Before timing, one warm round asserts the two paths' flush outputs
bit-identical to each other AND to eager evaluation of the same composed
batches by the seed per-interest engine. Reported: flush seconds per round
(compile time excluded via ``BrokerStats.rejit_s``), cohort passes per
flush, and the speedup ratio. Emits ``experiments/bench/BENCH_flush.json``.

    PYTHONPATH=src python -m benchmarks.run --only flush
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import (
    Broker,
    Dictionary,
    InterestExpr,
    IrapEngine,
    PushPolicy,
    StepCapacities,
)
from repro.core.propagation import ChangesetBatch

from .common import csv_row, save_json

N_SHAPES = 3


def _interest(i: int) -> InterestExpr:
    cls = f"cls{i % 6}"
    p = f"p{i % 6}"
    shape = i % N_SHAPES
    if shape == 0:
        bgp = [("?a", "rdf:type", cls), ("?a", p, "?v")]
        ogp = []
    elif shape == 1:
        bgp = [("?a", "rdf:type", cls)]
        ogp = []
    else:
        bgp = [("?a", "rdf:type", cls), ("?a", p, "?v")]
        ogp = [("?a", "foaf:page", "?w")]
    return InterestExpr.parse(
        source="synthetic://flush", target=f"local://sub{i}", bgp=bgp, ogp=ogp
    )


def _caps() -> StepCapacities:
    return StepCapacities(
        n_removed=256, n_added=256, tau=1024, rho=512, pulls=256, fanout=4
    )


def _stream(
    d: Dictionary, n: int, rows_per_side: int = 48, seed: int = 0
) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)

    def rows(k):
        out = []
        for _ in range(k):
            e = f"e{rng.integers(0, 400)}"
            kind = rng.integers(0, 4)
            if kind == 0:
                out.append((e, "rdf:type", f"cls{rng.integers(0, 6)}"))
            elif kind == 1:
                out.append((e, f"p{rng.integers(0, 6)}", f"o{rng.integers(0, 40)}"))
            else:
                out.append((e, f"noise{rng.integers(0, 6)}", f"o{rng.integers(0, 40)}"))
        return d.encode_triples(out)

    return [
        (rows(rows_per_side // 2), rows(rows_per_side)) for _ in range(n)
    ]


def _composed(changesets, start_id=1):
    batch = ChangesetBatch.fresh(*changesets[0], start_id)
    for i, cs in enumerate(changesets[1:], start=start_id + 1):
        batch.extend(*cs, i)
    return batch.arrays()


def _assert_outputs_equal(got, want, label):
    for field in ("r", "r_i", "r_prime", "a", "a_i"):
        gf, wf = getattr(got, field), getattr(want, field)
        if not np.array_equal(np.asarray(gf.spo), np.asarray(wf.spo)):
            raise AssertionError(f"deferred outputs diverge: {label}/{field}")


def _build(d: Dictionary, n_subs: int, device: bool) -> Tuple[Broker, list]:
    broker = Broker(d, deferred_device_resident=device)
    policy = PushPolicy.max_staleness(1e9)  # only explicit flush fires
    subs = [
        broker.subscribe(_interest(i), _caps(), policy=policy)
        for i in range(n_subs)
    ]
    return broker, subs


def _run_rounds(
    broker: Broker, subs: list, stream, n_rounds: int, per_round: int
) -> dict:
    """Each round: feed, flush half (frontier split), feed, flush all —
    so every full flush drains two distinct frontiers."""
    half = subs[: len(subs) // 2]
    it = iter(stream)
    warm_stats = len(broker.stats)
    for _ in range(n_rounds):
        for _ in range(per_round):
            broker.process_changeset(*next(it))
        broker.flush(subs=half)
        for _ in range(per_round):
            broker.process_changeset(*next(it))
        broker.flush()
    flush_stats = [
        st for st in broker.stats[warm_stats:] if st.total_added == 0
    ]
    eval_s = sum(st.elapsed_s - st.rejit_s for st in flush_stats)
    return {
        "n_flushes": len(flush_stats),
        "flush_eval_s": eval_s,
        "flush_eval_s_per_round": eval_s / max(1, n_rounds),
        "cohort_passes": sum(st.n_cohort_passes for st in flush_stats),
        "rejit_s": sum(st.rejit_s for st in broker.stats[warm_stats:]),
    }


def run(scale: float = 1.0, n_subs: int = 12, n_rounds: int = 6,
        per_round: int = 4) -> str:
    need = 2 * per_round * (n_rounds + 1)
    streams = {}
    brokers = {}
    for name, device in (("device", True), ("roundtrip", False)):
        d = Dictionary()
        stream = _stream(d, need, seed=0)
        brokers[name] = _build(d, n_subs, device)
        streams[name] = stream

    # -- warm + parity round: both paths vs eager composed-batch evaluation
    warm = {name: streams[name][: 2 * per_round] for name in brokers}
    flushed = {}
    for name, (broker, subs) in brokers.items():
        for cs in warm[name]:
            broker.process_changeset(*cs)
        flushed[name] = broker.flush()
    d_ref = Dictionary()
    ref_stream = _stream(d_ref, need, seed=0)
    engine = IrapEngine(d_ref)
    refs = [
        engine.register_interest(_interest(i), _caps())
        for i in range(n_subs)
    ]
    d_np, a_np = _composed(ref_stream[: 2 * per_round])
    for k, ref in enumerate(refs):
        want = ref.apply(d_np, a_np)
        _assert_outputs_equal(flushed["device"][k], want, f"device/{k}")
        _assert_outputs_equal(flushed["roundtrip"][k], want, f"roundtrip/{k}")

    # -- timed rounds (steady state: executables + statics cached)
    results = {}
    for name, (broker, subs) in brokers.items():
        results[name] = _run_rounds(
            broker, subs, streams[name][2 * per_round :], n_rounds, per_round
        )
        results[name]["n_subscribers"] = n_subs
        results[name]["changesets_per_round"] = 2 * per_round

    speedup = results["roundtrip"]["flush_eval_s"] / max(
        1e-9, results["device"]["flush_eval_s"]
    )
    pass_ratio = results["roundtrip"]["cohort_passes"] / max(
        1, results["device"]["cohort_passes"]
    )
    save_json(
        "BENCH_flush",
        {
            "device_resident": results["device"],
            "round_trip_baseline": results["roundtrip"],
            "flush_speedup": speedup,
            "cohort_pass_ratio": pass_ratio,
            "parity": {
                "checked_against_eager_composed_batches": True,
                "subscribers_checked": n_subs,
            },
            "scale": scale,
        },
    )
    us = results["device"]["flush_eval_s_per_round"] * 1e6
    return csv_row(
        "broker_flush",
        us,
        f"speedup_x={speedup:.2f};passes "
        f"{results['device']['cohort_passes']}"
        f"vs{results['roundtrip']['cohort_passes']};subs={n_subs}",
    )
