"""Interpret-mode parity of the multi-word bank kernels vs the oracles.

Covers the single-invocation multi-word emit
(:func:`repro.kernels.triple_match.triple_match_words_pallas`) and the
fused emit + lane-routing + member-mask kernel
(:func:`repro.kernels.triple_match.triple_match_lanes_pallas`) against the
pure-jnp oracles in :mod:`repro.kernels.ref` AND against the historical
chunked composition (per-32-lane :func:`ref.pattern_bitmask_ref` words +
:func:`ops.lane_bits_batched` routing), including W = 1 banks,
non-multiple-of-32 bank widths, and all-tombstone words.

Deliberately hypothesis-free (seeded ``numpy.random``): these are tier-1
kernel parity tests and must run in every CI configuration, including ones
without the optional dev dependencies.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.triple_match import (
    BLOCK_ROWS,
    triple_match_lanes_pallas,
    triple_match_words_pallas,
)

PAD = ref.PAD
TILE = 128 * BLOCK_ROWS


def _random_spo(rng, n, vocab=9, pad_frac=0.1):
    spo = rng.integers(0, vocab, size=(n, 3)).astype(np.int32)
    spo[rng.random(n) < pad_frac] = PAD
    return spo


def _random_bank(rng, n_pat, vocab=9, tombstone_frac=0.0):
    pats = rng.integers(-1, vocab, size=(n_pat, 3)).astype(np.int32)
    if tombstone_frac:
        pats[rng.random(n_pat) < tombstone_frac] = PAD
    return pats


def _chunked_words(spo, pats):
    """The pre-fusion reference: one pattern_bitmask_ref pass per word."""
    n_pat = pats.shape[0]
    n_words = max(1, -(-n_pat // 32))
    words = []
    for w in range(n_words):
        chunk = pats[w * 32 : (w + 1) * 32]
        if chunk.shape[0] == 0:
            words.append(jnp.zeros((spo.shape[0],), jnp.uint32))
        else:
            words.append(ref.pattern_bitmask_ref(spo, chunk))
    return jnp.stack(words, axis=1)


# ---------------------------------------------------------------------------
# multi-word emit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_pat", [1, 5, 31, 32, 33, 40, 63, 64, 65])
def test_words_ref_matches_chunked(n_pat):
    """Vectorized multi-word oracle == historical per-32-lane chunking
    (W = 1 and every non-multiple-of-32 width around the word boundary)."""
    rng = np.random.default_rng(n_pat)
    spo = jnp.asarray(_random_spo(rng, 777))
    pats = jnp.asarray(_random_bank(rng, n_pat))
    got = ref.pattern_bitmask_words_ref(spo, pats)
    want = _chunked_words(spo, pats)
    assert got.shape == (777, max(1, -(-n_pat // 32)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_pat", [1, 5, 32, 33, 40, 64])
@pytest.mark.parametrize("n", [1, 100, TILE - 1, TILE, TILE + 1])
def test_words_kernel_matches_ref(n_pat, n):
    """One Pallas invocation (interpret mode) emits all W words exactly."""
    rng = np.random.default_rng(n_pat * 1000 + n)
    spo = jnp.asarray(_random_spo(rng, n))
    pats = jnp.asarray(_random_bank(rng, n_pat, tombstone_frac=0.15))
    got = ops.pattern_bitmask_words(spo, pats, use_kernel=True)
    want = ref.pattern_bitmask_words_ref(spo, pats)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_words_kernel_direct_tile_aligned():
    """The raw kernel wrapper (uint32[W, N] layout) on an exact tile."""
    rng = np.random.default_rng(7)
    spo = jnp.asarray(_random_spo(rng, TILE))
    pats = jnp.asarray(_random_bank(rng, 40))
    got = triple_match_words_pallas(spo, pats, interpret=True)
    want = ref.pattern_bitmask_words_ref(spo, pats)
    assert got.shape == (2, TILE)
    np.testing.assert_array_equal(np.asarray(got.T), np.asarray(want))


def test_words_all_tombstone_word():
    """A word whose 32 lanes are all tombstones emits exactly zero — and
    the PAD sentinel row can never match a valid triple."""
    rng = np.random.default_rng(11)
    spo = jnp.asarray(_random_spo(rng, 500, pad_frac=0.3))
    pats = np.full((64, 3), PAD, np.int32)  # word 1 entirely dead
    pats[:32] = _random_bank(rng, 32)
    pats = jnp.asarray(pats)
    for use_kernel in (False, True):
        words = ops.pattern_bitmask_words(spo, pats, use_kernel=use_kernel)
        np.testing.assert_array_equal(
            np.asarray(words[:, 1]), np.zeros((500,), np.uint32)
        )
        np.testing.assert_array_equal(
            np.asarray(words[:, 0]),
            np.asarray(ref.pattern_bitmask_ref(spo, pats[:32])),
        )


def test_words_matcher_hook_still_chunked():
    """A custom matcher (distribution/testing hook) must observe one pass
    per 32-lane word — the fused kernel may not bypass it."""
    calls = []

    def spy(spo, chunk):
        calls.append(int(chunk.shape[0]))
        return ref.pattern_bitmask_ref(spo, chunk)

    rng = np.random.default_rng(3)
    spo = jnp.asarray(_random_spo(rng, 64))
    pats = jnp.asarray(_random_bank(rng, 40))
    got = ops.pattern_bitmask_words(spo, pats, matcher=spy)
    assert calls == [32, 8]
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.pattern_bitmask_words_ref(spo, pats))
    )


# ---------------------------------------------------------------------------
# segmented emit (delta-encoded frontier chains)
# ---------------------------------------------------------------------------

from repro.kernels.triple_match import triple_match_words_segmented_pallas


def _masked_planes(spo, pats, seg, n_seg):
    """The pre-delta reference: one full words pass per segment, each over
    only that segment's member rows (non-members replaced by PAD rows)."""
    planes = []
    for f in range(n_seg):
        m = (np.asarray(seg) >> f) & 1
        spo_f = np.where(
            (m == 1)[:, None], np.asarray(spo), np.full((1, 3), PAD, np.int32)
        )
        w = ref.pattern_bitmask_words_ref(jnp.asarray(spo_f), pats)
        # PAD substitution kills the match, matching the masked-plane spec
        planes.append(jnp.where(jnp.asarray(m == 1)[:, None], w, jnp.uint32(0)))
    return jnp.stack(planes)


@pytest.mark.parametrize("n_seg", [1, 2, 5, 32])
@pytest.mark.parametrize("n_pat", [1, 33, 64])
def test_segmented_ref_matches_per_segment_passes(n_seg, n_pat):
    """One masked union pass == n_seg independent per-frontier passes."""
    rng = np.random.default_rng(n_seg * 100 + n_pat)
    spo = jnp.asarray(_random_spo(rng, 300))
    pats = jnp.asarray(_random_bank(rng, n_pat, tombstone_frac=0.1))
    seg = jnp.asarray(
        rng.integers(0, 2 ** min(n_seg + 2, 31), size=300).astype(np.int32)
    )
    got = ref.pattern_bitmask_words_segmented_ref(spo, pats, seg, n_seg)
    want = _masked_planes(spo, pats, seg, n_seg)
    assert got.shape == (n_seg, 300, max(1, -(-n_pat // 32)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_pat", [1, 5, 33, 64])
@pytest.mark.parametrize("n", [1, 100, TILE, TILE + 1])
def test_segmented_kernel_matches_ref(n_pat, n):
    """One Pallas invocation (interpret mode) emits all segment planes."""
    rng = np.random.default_rng(n_pat * 1000 + n)
    n_seg = 3
    spo = jnp.asarray(_random_spo(rng, n))
    pats = jnp.asarray(_random_bank(rng, n_pat, tombstone_frac=0.15))
    seg = jnp.asarray(rng.integers(0, 2**n_seg, size=n).astype(np.int32))
    got = ops.pattern_bitmask_words_segmented(
        spo, pats, seg, n_seg, use_kernel=True
    )
    want = ref.pattern_bitmask_words_segmented_ref(spo, pats, seg, n_seg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segmented_kernel_direct_tile_aligned():
    """The raw kernel wrapper (uint32[F, W, N] layout) on an exact tile."""
    rng = np.random.default_rng(17)
    spo = jnp.asarray(_random_spo(rng, TILE))
    pats = jnp.asarray(_random_bank(rng, 40))
    seg = jnp.asarray(rng.integers(0, 4, size=TILE).astype(np.int32))
    got = triple_match_words_segmented_pallas(
        spo, pats, seg, n_seg=2, interpret=True
    )
    want = ref.pattern_bitmask_words_segmented_ref(spo, pats, seg, 2)
    assert got.shape == (2, 2, TILE)
    np.testing.assert_array_equal(
        np.asarray(jnp.swapaxes(got, 1, 2)), np.asarray(want)
    )


def test_segmented_zero_membership_and_high_bits():
    """Rows with no membership bits emit zero in every plane; bits at or
    above n_seg are ignored."""
    rng = np.random.default_rng(19)
    spo = jnp.asarray(_random_spo(rng, 200, pad_frac=0.0, vocab=3))
    pats = jnp.asarray(_random_bank(rng, 33, vocab=3))
    seg = np.zeros(200, np.int32)
    seg[::2] = 1 << 5  # only bits >= n_seg set: still zero planes
    for use_kernel in (False, True):
        got = ops.pattern_bitmask_words_segmented(
            spo, pats, jnp.asarray(seg), 2, use_kernel=use_kernel
        )
        assert not np.asarray(got).any()
    # all-members plane equals the plain words pass
    seg_all = jnp.asarray(np.full(200, 1, np.int32))
    for use_kernel in (False, True):
        got = ops.pattern_bitmask_words_segmented(
            spo, pats, seg_all, 1, use_kernel=use_kernel
        )
        want = ops.pattern_bitmask_words(spo, pats, use_kernel=use_kernel)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want))


def test_segmented_matcher_hook_one_pass():
    """A custom matcher observes ONE pass per 32-lane word — never one per
    segment — and the masked planes still match the oracle."""
    calls = []

    def spy(spo, chunk):
        calls.append(int(chunk.shape[0]))
        return ref.pattern_bitmask_ref(spo, chunk)

    rng = np.random.default_rng(13)
    spo = jnp.asarray(_random_spo(rng, 64))
    pats = jnp.asarray(_random_bank(rng, 40))
    seg = jnp.asarray(rng.integers(0, 16, size=64).astype(np.int32))
    got = ops.pattern_bitmask_words_segmented(spo, pats, seg, 4, matcher=spy)
    assert calls == [32, 8]  # one chunked pass total, not per segment
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.pattern_bitmask_words_segmented_ref(spo, pats, seg, 4)),
    )


def test_segmented_rejects_bad_n_seg():
    rng = np.random.default_rng(3)
    spo = jnp.asarray(_random_spo(rng, 8))
    pats = jnp.asarray(_random_bank(rng, 4))
    seg = jnp.zeros(8, jnp.int32)
    for bad in (0, 33):
        with pytest.raises(ValueError):
            ops.pattern_bitmask_words_segmented(spo, pats, seg, bad)


# ---------------------------------------------------------------------------
# fused emit + lane routing + member mask
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "n_pat,r,nt", [(1, 1, 1), (5, 2, 3), (33, 4, 2), (64, 8, 4)]
)
def test_lane_kernel_matches_composed_pipeline(n_pat, r, nt):
    """Fused kernel == per-member multi-word emit + lane_bits_batched,
    including masked (padding) members forced to zero."""
    rng = np.random.default_rng(n_pat * 100 + r * 10 + nt)
    spo_b = np.stack([_random_spo(rng, 300) for _ in range(r)])
    pats = jnp.asarray(_random_bank(rng, n_pat, tombstone_frac=0.1))
    lanes = jnp.asarray(
        rng.integers(0, n_pat, size=(r, nt)).astype(np.int32)
    )
    active = jnp.asarray(rng.random(r) < 0.7)
    spo_j = jnp.asarray(spo_b)

    words = jnp.stack(
        [ref.pattern_bitmask_words_ref(spo_j[k], pats) for k in range(r)]
    )
    want = ops.lane_bits_batched(words, lanes, active=active)
    for use_kernel in (False, True):
        got = ops.pattern_lane_bits_batched(
            spo_j, pats, lanes, active, use_kernel=use_kernel
        )
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want), err_msg=str(use_kernel)
        )
    got_ref = ref.pattern_lane_bits_ref(spo_j, pats, lanes, active)
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))


def test_lane_kernel_direct_tile_aligned():
    """The raw fused kernel on an exact tile with an inactive member."""
    rng = np.random.default_rng(23)
    r, nt = 2, 3
    spo_b = jnp.asarray(np.stack([_random_spo(rng, TILE) for _ in range(r)]))
    pats = jnp.asarray(_random_bank(rng, 40))
    lanes = jnp.asarray(rng.integers(0, 40, size=(r, nt)).astype(np.int32))
    act = jnp.asarray(np.array([[1], [0]], np.int32))
    got = triple_match_lanes_pallas(spo_b, pats, lanes, act, interpret=True)
    want = ref.pattern_lane_bits_ref(
        spo_b, pats, lanes, jnp.asarray([True, False])
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert not np.asarray(got[1]).any()  # masked member: all zeros


def test_lane_kernel_active_none_means_all_active():
    rng = np.random.default_rng(29)
    spo_b = jnp.asarray(np.stack([_random_spo(rng, 100) for _ in range(3)]))
    pats = jnp.asarray(_random_bank(rng, 5))
    lanes = jnp.asarray(rng.integers(0, 5, size=(3, 2)).astype(np.int32))
    all_on = jnp.asarray(np.ones(3, bool))
    for use_kernel in (False, True):
        got = ops.pattern_lane_bits_batched(
            spo_b, pats, lanes, use_kernel=use_kernel
        )
        want = ops.pattern_lane_bits_batched(
            spo_b, pats, lanes, all_on, use_kernel=use_kernel
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
