"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
)
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.kernels import merge_join, ops, ref
from repro.kernels.triple_match import BLOCK_ROWS, triple_match_pallas

HSETTINGS = settings(
    max_examples=25, deadline=None, suppress_health_check=[HealthCheck.too_slow]
)
PAD = ref.PAD


def sorted_store(rows: np.ndarray, capacity: int) -> jnp.ndarray:
    rows = np.unique(rows.astype(np.int32), axis=0) if rows.size else rows.reshape(0, 3)
    out = np.full((capacity, 3), PAD, np.int32)
    out[: rows.shape[0]] = rows[np.lexsort((rows[:, 2], rows[:, 1], rows[:, 0]))] if rows.size else rows
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# triple_match
# ---------------------------------------------------------------------------
@given(
    n=st.integers(1, 3000),
    n_pat=st.integers(1, 32),
    vocab=st.integers(2, 40),
    seed=st.integers(0, 2**31 - 1),
)
@HSETTINGS
def test_triple_match_matches_ref(n, n_pat, vocab, seed):
    rng = np.random.default_rng(seed)
    spo = rng.integers(0, vocab, size=(n, 3)).astype(np.int32)
    # sprinkle PAD rows
    pad_rows = rng.random(n) < 0.1
    spo[pad_rows] = np.iinfo(np.int32).max
    pats = rng.integers(-1, vocab, size=(n_pat, 3)).astype(np.int32)
    got = ops.pattern_bitmask(jnp.asarray(spo), jnp.asarray(pats), use_kernel=True)
    want = ref.pattern_bitmask_ref(jnp.asarray(spo), jnp.asarray(pats))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_triple_match_exact_tile_boundary():
    tile = 128 * BLOCK_ROWS
    rng = np.random.default_rng(0)
    for n in (tile, tile * 2, tile - 1, tile + 1):
        spo = rng.integers(0, 9, size=(n, 3)).astype(np.int32)
        pats = jnp.asarray([[1, -1, 2], [-1, -1, -1]], jnp.int32)
        got = ops.pattern_bitmask(jnp.asarray(spo), pats, use_kernel=True)
        want = ref.pattern_bitmask_ref(jnp.asarray(spo), pats)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_triple_match_wildcard_only_pattern():
    spo = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)
    pats = jnp.asarray([[-1, -1, -1]], jnp.int32)
    got = ops.pattern_bitmask(spo, pats, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), [1, 1])


# ---------------------------------------------------------------------------
# merge_join
# ---------------------------------------------------------------------------
@given(
    s_rows=st.integers(0, 400),
    q_rows=st.integers(1, 3000),
    vocab=st.integers(2, 25),
    seed=st.integers(0, 2**31 - 1),
)
@HSETTINGS
def test_merge_probe_matches_ref(s_rows, q_rows, vocab, seed):
    rng = np.random.default_rng(seed)
    store = sorted_store(
        rng.integers(0, vocab, size=(s_rows, 3)), max(merge_join.STORE_BLOCK, 2048)
    )
    queries = jnp.asarray(
        rng.integers(0, vocab, size=(q_rows, 3)).astype(np.int32)
    )
    i_ref, f_ref = ref.merge_probe_ref(store, queries)
    i_k, f_k = ops.merge_probe(store, queries, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_ref))


def test_merge_probe_skew_falls_back():
    """Store too large for one window -> transparent XLA fallback, still exact."""
    rng = np.random.default_rng(1)
    big = merge_join.STORE_BLOCK * 4
    store = sorted_store(rng.integers(0, 2000, size=(big, 3)), big)
    queries = jnp.asarray(rng.integers(0, 2000, size=(512, 3)).astype(np.int32))
    i_ref, f_ref = ref.merge_probe_ref(store, queries)
    i_k, f_k = ops.merge_probe(store, queries, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_ref))


def test_merge_probe_duplicates_and_pads():
    store = sorted_store(np.asarray([[1, 1, 1], [1, 1, 1], [2, 2, 2]]), 2048)
    queries = jnp.asarray(
        [[1, 1, 1], [2, 2, 2], [3, 3, 3], [1, 1, 1]], jnp.int32
    )
    i_k, f_k = ops.merge_probe(store, queries, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(f_k), [True, True, False, True])


def test_pattern_bitmask_default_path_is_ref():
    spo = jnp.asarray([[0, 1, 2]], jnp.int32)
    pats = jnp.asarray([[0, -1, -1]], jnp.int32)
    got = ops.pattern_bitmask(spo, pats)  # default: XLA path on CPU
    np.testing.assert_array_equal(np.asarray(got), [1])


def test_merge_probe_windowed_prefetch_variant():
    """The scalar-prefetch (TPU production) variant matches the oracle in
    interpret mode: per-block store windows stream via the index_map."""
    rng = np.random.default_rng(7)
    s = merge_join.STORE_BLOCK * 2
    # store: s distinct sorted rows; queries: every 2nd store row (plus a few
    # misses) -> each query block's covering range aligns to one window
    base = np.arange(s, dtype=np.int32)
    store = np.stack([base // 64, (base // 8) % 8, base % 8], axis=1)
    q = store[:: s // (merge_join.QUERY_BLOCK * 2)].copy()
    q[::5, 2] += 1  # sprinkle misses
    q = q[np.lexsort((q[:, 2], q[:, 1], q[:, 0]))][: merge_join.QUERY_BLOCK * 2]

    i_ref, f_ref = ref.merge_probe_ref(jnp.asarray(store), jnp.asarray(q))
    firsts = q[0 :: merge_join.QUERY_BLOCK]
    starts, _ = ref.merge_probe_ref(jnp.asarray(store), jnp.asarray(firsts))
    win = (np.asarray(starts) // merge_join.STORE_BLOCK).astype(np.int32)
    # precondition: every block's range fits its window (else ops.py falls back)
    lasts = q[merge_join.QUERY_BLOCK - 1 :: merge_join.QUERY_BLOCK]
    ends, _ = ref.merge_probe_ref(jnp.asarray(store), jnp.asarray(lasts))
    assert np.all(np.asarray(ends) + 1 <= (win + 1) * merge_join.STORE_BLOCK)

    i_k, f_k = merge_join.merge_probe_windowed(
        jnp.asarray(store), jnp.asarray(win), jnp.asarray(q), interpret=True
    )
    np.testing.assert_array_equal(np.asarray(f_k).astype(bool), np.asarray(f_ref))
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_ref))
