"""Per-architecture smoke tests: reduced config, one forward/train/decode step
on CPU, asserting output shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import build_model

B, S = 2, 16


def make_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["enc_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["img_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_img_tokens, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    api = build_model(cfg)
    rng = np.random.default_rng(0)
    params = api.init(jax.random.key(0))
    batch = make_batch(cfg, rng)

    loss, metrics = api.train_loss(params, batch)
    assert np.isfinite(float(loss)), arch

    grads = jax.grad(lambda p: api.train_loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.all(np.isfinite(np.asarray(l))) for l in leaves), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    api = build_model(cfg)
    rng = np.random.default_rng(1)
    params = api.init(jax.random.key(1))
    batch = dict(make_batch(cfg, rng), max_seq=S + 4)

    logits, cache = api.prefill(params, batch)
    assert logits.shape == (B, cfg.padded_vocab), arch
    assert np.all(np.isfinite(np.asarray(logits[:, : cfg.vocab]))), arch

    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B,)), jnp.int32)
    logits2, cache2 = api.decode_step(params, cache, tok, jnp.int32(S))
    assert logits2.shape == (B, cfg.padded_vocab), arch
    assert np.all(np.isfinite(np.asarray(logits2[:, : cfg.vocab]))), arch


@pytest.mark.parametrize("arch", ["yi-34b", "falcon-mamba-7b", "zamba2-7b",
                                  "whisper-medium", "llama-3.2-vision-90b",
                                  "gemma3-4b"])
def test_decode_matches_prefill(arch):
    """Teacher-forcing consistency: step-by-step decode logits == full-seq
    forward logits at the same positions (the strictest cache test).

    Run in float32: cache correctness is exact there (<= 3e-6 across every
    arch), whereas bfloat16 accumulation-order differences between the two
    paths reach ~0.03 on the SSM hybrids — precision noise that forced a
    tolerance loose enough to mask real cache bugs.
    """
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    api = build_model(cfg)
    rng = np.random.default_rng(2)
    params = api.init(jax.random.key(2))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens, "max_seq": S}
    if cfg.family == "encdec":
        batch["enc_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["img_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_img_tokens, cfg.d_model)), jnp.float32
        )

    # full prefill over S-1 tokens, then decode token S-1
    pre_batch = dict(batch, tokens=tokens[:, : S - 1])
    _, cache = api.prefill(params, pre_batch)
    step_logits, _ = api.decode_step(
        params, cache, tokens[:, S - 1], jnp.int32(S - 1)
    )
    full_logits, _ = api.prefill(params, dict(batch, tokens=tokens))
    np.testing.assert_allclose(
        np.asarray(step_logits[:, : cfg.vocab]),
        np.asarray(full_logits[:, : cfg.vocab]),
        rtol=1e-4,
        atol=1e-4,
    )


def test_full_configs_instantiable():
    """Full configs build ModelApis and report sane param counts (no init)."""
    from repro.configs import all_configs

    counts = {}
    for name, cfg in all_configs().items():
        api = build_model(cfg)
        counts[name] = cfg.n_params
    assert counts["kimi-k2-1t-a32b"] > 0.9e12, counts["kimi-k2-1t-a32b"]
    assert 25e9 < counts["yi-34b"] < 45e9, counts["yi-34b"]
    assert counts["granite-moe-3b-a800m"] < 5e9
    assert 5e9 < counts["falcon-mamba-7b"] < 9e9, counts["falcon-mamba-7b"]
