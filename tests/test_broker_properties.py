"""Property tests for the multi-subscriber broker (fused == looped).

Random interest sets + changesets: the fused broker step must equal running
the per-interest seed step for every subscriber, including bitset-lane
routing through a deduplicated pattern bank and the >32-pattern chunked
path (two uint32 words). Steps are compiled once per plan combination at
module scope, so hypothesis examples only vary data.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
)
import jax.numpy as jnp
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    Dictionary,
    InterestExpr,
    StepCapacities,
    build_pattern_bank,
    make_broker_step,
    make_interest_step,
    to_set,
)
from repro.core.interest import compile_interest
from repro.core.triples import from_numpy
from repro.kernels import ops, ref

# ---------------------------------------------------------------------------
# mini-universe (mirrors test_properties.py) + wide predicate space for the
# chunked >32-lane bank
# ---------------------------------------------------------------------------
DICT = Dictionary()
TERMS = (
    [f"s{i}" for i in range(6)]
    + ["type", "goals", "label"]
    + [f"p{i}" for i in range(36)]
    + [f"o{i}" for i in range(4)]
    + ["Athlete", "Team"]
)
for t in TERMS:
    DICT.encode_term(t)
R_CAP = DICT.id_capacity
K = 8
M_CAP, TAU_CAP, RHO_CAP = 10, 48, 32
CAPS = StepCapacities(
    n_removed=M_CAP, n_added=M_CAP, tau=TAU_CAP, rho=RHO_CAP,
    pulls=4096, fanout=K,
)

EXPRS = {
    "star2": InterestExpr.parse(
        "g", "t", bgp=[("?a", "type", "Athlete"), ("?a", "goals", "?g")]
    ),
    "star2_ogp": InterestExpr.parse(
        "g", "t",
        bgp=[("?a", "type", "Athlete"), ("?a", "goals", "?g")],
        ogp=[("?a", "p0", "?h")],
    ),
    "single": InterestExpr.parse("g", "t", bgp=[("?a", "goals", "?g")]),
    "football": InterestExpr.parse(
        "g", "t",
        bgp=[
            ("?f", "type", "Athlete"),
            ("?f", "p1", "?t"),
            ("?t", "label", "?n"),
        ],
    ),
    "object_root": InterestExpr.parse(
        "g", "t", bgp=[("?x", "p0", "?a"), ("?a", "type", "Athlete")]
    ),
}
# three interests of 12 root-star patterns each over disjoint predicates:
# 36 distinct bank lanes -> 2 bitset words (the chunked path)
for c in range(3):
    EXPRS[f"wide{c}"] = InterestExpr.parse(
        "g", "t",
        bgp=[("?a", f"p{12 * c + i}", "?v%d" % i) for i in range(12)],
    )

PLANS = {k: compile_interest(e, DICT) for k, e in EXPRS.items()}
STEPS = {
    k: make_interest_step(p, id_capacity=R_CAP * CAPS.id_headroom, caps=CAPS)
    for k, p in PLANS.items()
}

COMBOS = {
    "dedup_pair": ("star2", "single"),  # shared goals pattern dedups
    "mixed3": ("star2_ogp", "football", "object_root"),
    "twins": ("star2", "star2"),  # identical interests share every lane
    "chunked": ("wide0", "wide1", "wide2", "star2"),  # 38 raw / 36 lanes? >32
}
BANKS = {name: build_pattern_bank([PLANS[k] for k in keys])
         for name, keys in COMBOS.items()}
BROKER_STEPS = {
    name: make_broker_step(
        BANKS[name],
        [PLANS[k] for k in keys],
        [CAPS] * len(keys),
        [R_CAP * CAPS.id_headroom] * len(keys),
    )
    for name, keys in COMBOS.items()
}
assert BANKS["chunked"].n_lanes > 32 and BANKS["chunked"].n_words == 2
assert BANKS["twins"].n_lanes == PLANS["star2"].n_total

SUBJ = [DICT.lookup(f"s{i}") for i in range(6)]
PRED = [DICT.lookup(x) for x in ("type", "goals", "label", "p0", "p1")] + [
    DICT.lookup(f"p{i}") for i in range(0, 36, 5)
]
OBJ = [DICT.lookup(x) for x in ("Athlete", "Team", "o0", "o1")] + SUBJ[:3]


def triple_set(max_size):
    return st.sets(
        st.tuples(
            st.sampled_from(SUBJ), st.sampled_from(PRED), st.sampled_from(OBJ)
        ),
        max_size=max_size,
    )


def np_rows(tris):
    if not tris:
        return np.zeros((0, 3), np.int32)
    return np.asarray(sorted(tris), np.int32)


HSETTINGS = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


@given(
    combo=st.sampled_from(sorted(COMBOS)),
    d_set=triple_set(8),
    a_set=triple_set(8),
    taus=st.lists(triple_set(8), min_size=4, max_size=4),
    rhos=st.lists(triple_set(6), min_size=4, max_size=4),
)
@HSETTINGS
def test_fused_equals_looped(combo, d_set, a_set, taus, rhos):
    keys = COMBOS[combo]
    n = len(keys)
    d_store = from_numpy(np_rows(d_set), M_CAP)
    a_store = from_numpy(np_rows(a_set), M_CAP)
    tau_stores = tuple(from_numpy(np_rows(taus[k]), TAU_CAP) for k in range(n))
    rho_stores = tuple(from_numpy(np_rows(rhos[k]), RHO_CAP) for k in range(n))

    tau1s, rho1s, outs = BROKER_STEPS[combo](
        d_store, a_store, tau_stores, rho_stores
    )
    for k, key in enumerate(keys):
        w_tau, w_rho, want = STEPS[key](
            d_store, a_store, tau_stores[k], rho_stores[k]
        )
        assert bool(outs[k].overflow) == bool(want.overflow), (combo, k)
        if bool(want.overflow):
            continue  # host loop would re-jit both paths identically
        for field in ("r", "r_i", "r_prime", "a", "a_i"):
            got_f = getattr(outs[k], field)
            want_f = getattr(want, field)
            assert np.array_equal(
                np.asarray(got_f.spo), np.asarray(want_f.spo)
            ), (combo, k, field)
        assert np.array_equal(np.asarray(tau1s[k].spo), np.asarray(w_tau.spo))
        assert np.array_equal(np.asarray(rho1s[k].spo), np.asarray(w_rho.spo))


@given(
    combo=st.sampled_from(sorted(COMBOS)),
    m=triple_set(10),
)
@HSETTINGS
def test_lane_routing_matches_per_plan_bitmask(combo, m):
    """Bank words + lane gather == each plan's own pattern bitmask."""
    keys = COMBOS[combo]
    bank = BANKS[combo]
    spo = from_numpy(np_rows(m), M_CAP).spo
    words = ops.pattern_bitmask_words(spo, jnp.asarray(bank.patterns))
    assert words.shape == (M_CAP, bank.n_words)
    for k, key in enumerate(keys):
        local = ops.lane_bits(words, bank.lanes[k])
        want = ref.pattern_bitmask_ref(spo, jnp.asarray(PLANS[key].patterns))
        np.testing.assert_array_equal(np.asarray(local), np.asarray(want))


@given(
    n_pat=st.integers(1, 40),
    n_lanes=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
@HSETTINGS
def test_lane_bits_random_banks(n_pat, n_lanes, seed):
    """Random banks (possibly >32 lanes) + random lane maps round-trip."""
    rng = np.random.default_rng(seed)
    pats = rng.integers(-1, 5, size=(n_pat, 3)).astype(np.int32)
    spo = jnp.asarray(rng.integers(0, 5, size=(32, 3)), jnp.int32)
    lanes = tuple(int(x) for x in rng.integers(0, n_pat, size=n_lanes))
    words = ops.pattern_bitmask_words(spo, jnp.asarray(pats))
    local = ops.lane_bits(words, lanes)
    want = ref.pattern_bitmask_ref(spo, jnp.asarray(pats[list(lanes)]))
    np.testing.assert_array_equal(np.asarray(local), np.asarray(want))


# ---------------------------------------------------------------------------
# subscription churn: membership changes recompile at most their own cohort
# ---------------------------------------------------------------------------

CHURN_DICT = Dictionary()
for _t in (
    ["type", "Athlete", "Team", "goals", "rank"]
    + [f"e{i}" for i in range(8)]
    + [f"o{i}" for i in range(4)]
):
    CHURN_DICT.encode_term(_t)
CHURN_CAPS = StepCapacities(
    n_removed=8, n_added=8, tau=256, rho=128, pulls=64, fanout=4
)
# executable cache shared across hypothesis examples (cohort keys are pure
# shape keys, so cross-broker reuse is sound and keeps examples cheap); the
# first cold example still exercises the compile-counting path for real.
# Must match Broker's own LRU cache type (OrderedDict).
from collections import OrderedDict

CHURN_EXEC_CACHE: "OrderedDict[tuple, object]" = OrderedDict()

_CHURN_EXPRS = [
    InterestExpr.parse(
        "g", "t0", bgp=[("?a", "type", "Athlete"), ("?a", "goals", "?v")]
    ),
    InterestExpr.parse(
        "g", "t1", bgp=[("?a", "type", "Team"), ("?a", "rank", "?v")]
    ),
    InterestExpr.parse("g", "t2", bgp=[("?a", "goals", "?v")]),
    InterestExpr.parse("g", "t3", bgp=[("?a", "rank", "?v")]),
]

_CHURN_SUBJ = [CHURN_DICT.lookup(f"e{i}") for i in range(8)]
_CHURN_PRED = [CHURN_DICT.lookup(x) for x in ("type", "goals", "rank")]
_CHURN_OBJ = [CHURN_DICT.lookup(x) for x in ("Athlete", "Team", "o0", "o1")]


def _churn_rows(draw, max_size):
    tris = draw(
        st.sets(
            st.tuples(
                st.sampled_from(_CHURN_SUBJ),
                st.sampled_from(_CHURN_PRED),
                st.sampled_from(_CHURN_OBJ),
            ),
            max_size=max_size,
        )
    )
    return np_rows(tris)


@given(data=st.data())
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_churn_recompile_bound(data):
    """Random subscribe/unsubscribe/process sequences never exceed one
    cohort recompile per membership change (and none without one)."""
    from repro.core import Broker

    broker = Broker(CHURN_DICT)
    broker._exec_cache = CHURN_EXEC_CACHE
    live = []
    i_next = 0
    ops = data.draw(
        st.lists(st.sampled_from("SUC"), min_size=2, max_size=8)
    )
    for op in ops:
        if op == "U" and live:
            broker.unsubscribe(live.pop(data.draw(
                st.integers(0, len(live) - 1))))
            changed = 1
        elif op == "C" and live:
            changed = 0
        else:  # subscribe (also the fallback when nothing is live)
            live.append(
                broker.subscribe(
                    _CHURN_EXPRS[i_next % len(_CHURN_EXPRS)], CHURN_CAPS
                )
            )
            i_next += 1
            changed = 1
        before = sum(broker.cohort_compiles.values())
        broker.process_changeset(
            _churn_rows(data.draw, 4), _churn_rows(data.draw, 4)
        )
        delta = sum(broker.cohort_compiles.values()) - before
        assert delta <= changed, (op, delta)


@given(combo=st.sampled_from(sorted(COMBOS)))
@HSETTINGS
def test_bank_lane_maps_recover_plan_patterns(combo):
    bank = BANKS[combo]
    for k, key in enumerate(COMBOS[combo]):
        np.testing.assert_array_equal(
            bank.patterns[list(bank.lanes[k])], PLANS[key].patterns
        )
    # dedup never invents patterns: every lane is used by some plan
    used = {lane for lanes in bank.lanes for lane in lanes}
    assert used == set(range(bank.n_lanes))


# ---------------------------------------------------------------------------
# subsumption lattice: distinct-interest evaluation + fanout is invisible.
# Random pools with duplicates and containment, plus subscribe/unsubscribe/
# re-subscribe churn: lattice-on == lattice-off == per-interest seed step,
# bit-identical at every fire.
# ---------------------------------------------------------------------------

from repro.core import Broker, to_numpy
from repro.core.interest import canonicalize_expr

LATT_DICT = Dictionary()
for _t in (
    ["type", "goals", "rank", "Athlete", "Team"]
    + [f"e{i}" for i in range(6)]
    + [f"o{i}" for i in range(4)]
):
    LATT_DICT.encode_term(_t)
LATT_CAPS = StepCapacities(
    n_removed=6, n_added=6, tau=64, rho=32, pulls=64, fanout=4
)
# pool with exact duplicates (0/2), a renaming (0/5), containment (1 and 4
# under 0), and a star reorder (3/6)
_LATT_POOL = [
    InterestExpr.parse("g", "t", bgp=[("?a", "goals", "?v")]),
    InterestExpr.parse("g", "t", bgp=[("e0", "goals", "?v")]),
    InterestExpr.parse("g", "t", bgp=[("?a", "goals", "?v")]),
    InterestExpr.parse(
        "g", "t", bgp=[("?a", "type", "Athlete"), ("?a", "goals", "?v")]
    ),
    InterestExpr.parse("g", "t", bgp=[("e1", "goals", "?v")]),
    InterestExpr.parse("g", "t", bgp=[("?z", "goals", "?w")]),
    InterestExpr.parse(
        "g", "t", bgp=[("?q", "goals", "?r"), ("?q", "type", "Athlete")]
    ),
]
_LATT_ID_CAP = LATT_DICT.id_capacity * LATT_CAPS.id_headroom
_LATT_STEPS = [
    make_interest_step(
        compile_interest(canonicalize_expr(e)[0], LATT_DICT),
        id_capacity=_LATT_ID_CAP,
        caps=LATT_CAPS,
    )
    for e in _LATT_POOL
]
LATT_EXEC_CACHE: "OrderedDict[tuple, object]" = OrderedDict()

_LATT_SUBJ = [LATT_DICT.lookup(f"e{i}") for i in range(6)]
_LATT_PRED = [LATT_DICT.lookup(x) for x in ("type", "goals", "rank")]
_LATT_OBJ = [LATT_DICT.lookup(x) for x in ("Athlete", "Team", "o0", "o1")]


def _latt_rows(draw, max_size):
    tris = draw(
        st.sets(
            st.tuples(
                st.sampled_from(_LATT_SUBJ),
                st.sampled_from(_LATT_PRED),
                st.sampled_from(_LATT_OBJ),
            ),
            max_size=max_size,
        )
    )
    return np_rows(tris)


def _latt_outs(o):
    if o is None:
        return None
    return tuple(
        to_numpy(getattr(o, f)) for f in ("r", "r_i", "r_prime", "a", "a_i")
    )


@given(data=st.data())
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_lattice_collapse_is_invisible_under_churn(data):
    """Lattice-on == lattice-off == seed oracle through random churn."""
    b_on = Broker(LATT_DICT, subsume_interests=True)
    b_off = Broker(LATT_DICT, subsume_interests=False)
    b_on._exec_cache = LATT_EXEC_CACHE
    b_off._exec_cache = LATT_EXEC_CACHE
    live = []  # (pool index, sub_on, sub_off, seed tau, seed rho)
    plan = data.draw(st.lists(st.sampled_from("SSUC"), min_size=2, max_size=7))
    for op in plan:
        if op == "U" and live:
            _, s_on, s_off, _, _ = live.pop(
                data.draw(st.integers(0, len(live) - 1))
            )
            b_on.unsubscribe(s_on)
            b_off.unsubscribe(s_off)
        elif op != "C" or not live:
            # subscribing >1 at a time lets fresh duplicates auto-join a
            # lane group (a changeset in between desyncs their frontiers,
            # which must — and does — keep them independent instead)
            for _ in range(data.draw(st.integers(1, 2))):
                i = data.draw(st.integers(0, len(_LATT_POOL) - 1))
                live.append((
                    i,
                    b_on.subscribe(_LATT_POOL[i], LATT_CAPS),
                    b_off.subscribe(_LATT_POOL[i], LATT_CAPS),
                    from_numpy(np.zeros((0, 3), np.int32), LATT_CAPS.tau),
                    from_numpy(np.zeros((0, 3), np.int32), LATT_CAPS.rho),
                ))
        rm = _latt_rows(data.draw, 4)
        ad = _latt_rows(data.draw, 5)
        outs_on = [_latt_outs(o) for o in b_on.process_changeset(rm, ad)]
        outs_off = [_latt_outs(o) for o in b_off.process_changeset(rm, ad)]
        assert len(outs_on) == len(outs_off) == len(live)
        d_store = from_numpy(rm, LATT_CAPS.n_removed)
        a_store = from_numpy(ad, LATT_CAPS.n_added)
        for k, (i, s_on, s_off, tau, rho) in enumerate(live):
            tau, rho, want = _LATT_STEPS[i](d_store, a_store, tau, rho)
            live[k] = (i, s_on, s_off, tau, rho)
            seed = _latt_outs(want)
            assert (outs_on[k] is None) == (outs_off[k] is None)
            if outs_on[k] is None:
                continue
            for f, (x, y, z) in enumerate(
                zip(outs_on[k], outs_off[k], seed)
            ):
                np.testing.assert_array_equal(x, y, err_msg=f"on/off {k}/{f}")
                np.testing.assert_array_equal(x, z, err_msg=f"on/seed {k}/{f}")
    # lattice-off never evaluates fewer slots than subscribers; lattice-on
    # never evaluates more than lattice-off
    assert b_off.distinct_interests == b_off.fanout_copies
    assert b_on.distinct_interests <= b_off.distinct_interests
    assert b_on.fanout_copies == b_off.fanout_copies
