"""SSM correctness: chunked scans vs naive per-step recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm as S
from repro.models.config import ModelConfig


def mamba1_cfg(chunk):
    return ModelConfig(
        name="m1", family="ssm", n_layers=1, d_model=16, n_heads=1,
        n_kv_heads=1, d_head=8, d_ff=0, vocab=7, ssm_kind="mamba1",
        d_state=4, expand=2, conv_dim=3, scan_chunk=chunk,
    )


def mamba2_cfg(chunk):
    return ModelConfig(
        name="m2", family="hybrid", n_layers=1, d_model=16, n_heads=1,
        n_kv_heads=1, d_head=8, d_ff=0, vocab=7, ssm_kind="mamba2",
        d_state=4, expand=2, conv_dim=3, ssm_head_dim=8, ssm_chunk=chunk,
    )


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mamba1_forward_equals_stepwise(chunk):
    cfg = mamba1_cfg(chunk)
    p = S.init_mamba1(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    y_full, state_full = S.mamba1_forward(p, x, cfg, return_state=True)

    state = S.mamba1_init_state(cfg, 2)
    ys = []
    for t in range(16):
        y_t, state = S.mamba1_step(p, x[:, t], state, cfg)
        ys.append(y_t)
    y_steps = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(y_steps), rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(state_full["ssm"]), np.asarray(state["ssm"]), rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(state_full["conv"]), np.asarray(state["conv"]), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mamba2_forward_equals_stepwise(chunk):
    cfg = mamba2_cfg(chunk)
    p = S.init_mamba2(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    y_full, state_full = S.mamba2_forward(p, x, cfg, return_state=True)

    state = S.mamba2_init_state(cfg, 2)
    ys = []
    for t in range(16):
        y_t, state = S.mamba2_step(p, x[:, t], state, cfg)
        ys.append(y_t)
    y_steps = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(y_steps), rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(state_full["ssm"]), np.asarray(state["ssm"]), rtol=2e-3, atol=2e-3
    )


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size."""
    b, s, h, p, n = 2, 32, 3, 4, 5
    key = jax.random.key(3)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bt = jax.random.normal(ks[3], (b, s, n))
    ct = jax.random.normal(ks[4], (b, s, n))
    y8, h8 = S.ssd_chunked(x, dt, a, bt, ct, 8)
    y32, h32 = S.ssd_chunked(x, dt, a, bt, ct, 32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h32), rtol=1e-4, atol=1e-4)
