"""Device-resident deferred evaluation == the host-round-trip baseline.

The PR 2 deferred path pulled every composed batch device→host and
re-uploaded it per fire, running one sequential pass per frontier. The
device-resident path consumes the batches' sorted device stores directly
and stacks same-shape cohorts across frontiers into one executable call.
Outputs (and all replica state) must stay bit-identical between the two —
and therefore to eager evaluation of the composed batches, which
tests/test_broker_scheduling.py pins against the round-trip path.
"""
import numpy as np
import pytest

from repro.core import (
    Broker,
    Dictionary,
    InterestExpr,
    PushPolicy,
    StepCapacities,
)

A = "rdf:type"
CAPS = StepCapacities(n_removed=16, n_added=16, tau=64, rho=64, pulls=32)


def _exprs():
    return [
        InterestExpr.parse(
            "g", "t0", bgp=[("?a", A, "c:Athlete"), ("?a", "p:goals", "?v")]
        ),
        InterestExpr.parse(
            "g", "t1", bgp=[("?a", A, "c:Team"), ("?a", "p:rank", "?v")]
        ),
        InterestExpr.parse("g", "t2", bgp=[("?a", "p:goals", "?v")]),
    ]


def _universe():
    d = Dictionary()
    tau0 = d.encode_triples(
        [
            ("e:1", A, "c:Athlete"),
            ("e:1", "p:goals", "10"),
            ("e:2", A, "c:Team"),
        ]
    )
    return d, tau0


def _stream(d, n, seed=0):
    rng = np.random.default_rng(seed)

    def rows(k):
        out = set()
        for _ in range(k):
            e = f"e:{rng.integers(0, 9)}"
            kind = rng.integers(0, 4)
            if kind == 0:
                out.add((e, A, f"c:{['Athlete', 'Team'][rng.integers(2)]}"))
            elif kind == 1:
                out.add((e, "p:goals", str(int(rng.integers(0, 30)))))
            elif kind == 2:
                out.add((e, "p:rank", str(int(rng.integers(0, 5)))))
            else:
                out.add((e, "p:noise", f"o{rng.integers(0, 6)}"))
        return d.encode_triples(sorted(out))

    return [
        (rows(int(rng.integers(0, 5))), rows(int(rng.integers(1, 7))))
        for _ in range(n)
    ]


def _twin_brokers(d, tau0, policies):
    """Two brokers over one dictionary: device-resident vs round-trip."""
    dev = Broker(d, deferred_device_resident=True)
    rtt = Broker(d, deferred_device_resident=False)
    exprs = _exprs()
    for i, pol in enumerate(policies):
        expr = exprs[i % len(exprs)]
        dev.subscribe(expr, CAPS, initial_target=tau0, policy=pol)
        rtt.subscribe(expr, CAPS, initial_target=tau0, policy=pol)
    return dev, rtt


def assert_results_identical(got, want, label):
    assert len(got) == len(want), label
    for k, (g, w) in enumerate(zip(got, want)):
        assert (g is None) == (w is None), (label, k)
        if g is None:
            continue
        for field in ("r", "r_i", "r_prime", "a", "a_i"):
            gf, wf = getattr(g, field), getattr(w, field)
            assert np.array_equal(
                np.asarray(gf.spo), np.asarray(wf.spo)
            ), (label, k, field)
            assert int(gf.n) == int(wf.n), (label, k, field)


def assert_states_identical(dev, rtt, label):
    for k, (sd, sr) in enumerate(zip(dev.subs, rtt.subs)):
        assert np.array_equal(
            np.asarray(sd.tau.spo), np.asarray(sr.tau.spo)
        ), (label, k, "tau")
        assert np.array_equal(
            np.asarray(sd.rho.spo), np.asarray(sr.rho.spo)
        ), (label, k, "rho")
        assert sd.since == sr.since, (label, k)


def test_device_resident_matches_round_trip_golden():
    """Mixed cadences (eager / every-2 / every-3) through both paths stay
    bit-identical step by step, and a multi-frontier flush stacks the
    same-shape cohorts into fewer passes than the sequential baseline."""
    d, tau0 = _universe()
    dev, rtt = _twin_brokers(
        d,
        tau0,
        [
            PushPolicy(),  # eager
            PushPolicy.every(2),
            PushPolicy.every(3),
            PushPolicy.every(3),  # same shape as sub 0 family, slow lane
        ],
    )
    for i, cs in enumerate(_stream(d, 5, seed=1)):
        got = dev.process_changeset(*cs)
        want = rtt.process_changeset(*cs)
        assert_results_identical(got, want, ("step", i))
        assert_states_identical(dev, rtt, ("step", i))

    # leave two distinct frontiers pending, then drain both paths at once
    got = dev.flush()
    want = rtt.flush()
    assert_results_identical(got, want, "flush")
    assert_states_identical(dev, rtt, "flush")
    if dev.stats and rtt.stats:
        dev_passes = dev.stats[-1].n_cohort_passes
        rtt_passes = rtt.stats[-1].n_cohort_passes
        assert dev_passes <= rtt_passes

    # nothing pending: both flushes are no-ops
    assert dev.flush() == [None] * len(dev.subs)
    assert rtt.flush() == [None] * len(rtt.subs)


def test_multi_frontier_flush_stacks_same_shape_cohorts():
    """Two same-shape subscribers stuck at different frontiers drain in ONE
    stacked cohort pass on the device-resident path (two sequentially on
    the baseline), with identical outputs."""
    d, tau0 = _universe()
    expr = _exprs()[0]
    # pre-encode the stream so the dictionary (and with it id_capacity,
    # part of the cohort key) is identical for both subscriptions
    stream = _stream(d, 4, seed=2)
    dev = Broker(d, deferred_device_resident=True)
    rtt = Broker(d, deferred_device_resident=False)
    for b in (dev, rtt):
        b.subscribe(
            expr, CAPS, initial_target=tau0, policy=PushPolicy.max_staleness(1e9)
        )
    for b in (dev, rtt):
        b.process_changeset(*stream[0])
        # second subscriber arrives mid-stream: its frontier starts later
        b.subscribe(
            expr, CAPS, initial_target=tau0, policy=PushPolicy.max_staleness(1e9)
        )
        for cs in stream[1:]:
            b.process_changeset(*cs)

    got, want = dev.flush(), rtt.flush()
    assert_results_identical(got, want, "stacked flush")
    assert_states_identical(dev, rtt, "stacked flush")
    # both subscribers share one shape cohort: the stacked path folds the
    # two frontiers into a single executable call
    assert dev.stats[-1].n_cohort_passes == 1
    assert rtt.stats[-1].n_cohort_passes == 2


def test_device_resident_property_random_streams():
    """Hypothesis sweep: random policies + random streams stay bit-identical
    between the device-resident and round-trip paths, including flushes."""
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
    )
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @given(
        seed=st.integers(0, 2**16),
        ks=st.lists(st.integers(1, 4), min_size=2, max_size=4),
        n_steps=st.integers(2, 6),
    )
    @settings(
        max_examples=10,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def prop(seed, ks, n_steps):
        d, tau0 = _universe()
        dev, rtt = _twin_brokers(
            d, tau0, [PushPolicy.every(k) for k in ks]
        )
        for i, cs in enumerate(_stream(d, n_steps, seed=seed)):
            got = dev.process_changeset(*cs)
            want = rtt.process_changeset(*cs)
            assert_results_identical(got, want, ("step", i))
        got, want = dev.flush(), rtt.flush()
        assert_results_identical(got, want, "flush")
        assert_states_identical(dev, rtt, "final")

    prop()


def _burst_rows(d, n_raw, n_distinct, seed=0):
    """n_raw triples drawn from an n_distinct-triple pool (duplicate-heavy:
    raw rows force capacity growth, composed rows stay small)."""
    rng = np.random.default_rng(seed)
    pool = [
        (f"e:{i % 50}", "p:goals", str(1000 + i)) for i in range(n_distinct)
    ]
    picks = [pool[rng.integers(0, n_distinct)] for _ in range(n_raw)]
    return d.encode_triples(picks)


def test_batch_capacity_decay():
    """A deferred frontier that grew through a duplicate-heavy burst decays
    back to a smaller pow2 bucket after `decay_patience` consecutive drains,
    and BrokerStats exposes the grow/shrink counts."""
    d, tau0 = _universe()
    broker = Broker(d, decay_patience=2)
    expr = _exprs()[0]
    # X is drained explicitly every round; Y defers forever, so its batch
    # survives every drain and is the decay candidate
    x = broker.subscribe(
        expr, CAPS, initial_target=tau0, policy=PushPolicy.max_staleness(1e9)
    )
    y = broker.subscribe(
        _exprs()[1], CAPS, initial_target=tau0,
        policy=PushPolicy.max_staleness(1e9),
    )
    z = np.zeros((0, 3), np.int32)

    # small first changeset: the shared batch starts at the 64-row floor
    broker.process_changeset(z, _burst_rows(d, 8, 8, seed=1))
    # duplicate-heavy burst: 200 raw rows force the pow2 bucket up, but the
    # composed distinct rows stay far below half the new allocation
    broker.process_changeset(z, _burst_rows(d, 200, 24, seed=2))
    batch = next(iter(broker._batches.values()))
    assert batch.capacity >= 256
    assert broker.batch_grows >= 1
    cap_peak = batch.capacity

    # each explicit drain of X is one decay check on Y's surviving batch;
    # patience=2 means the first check only arms the streak
    broker.process_changeset(z, _burst_rows(d, 4, 4, seed=3))
    broker.flush(subs=[x])
    assert batch.capacity == cap_peak and broker.batch_shrinks == 0
    broker.process_changeset(z, _burst_rows(d, 4, 4, seed=4))
    broker.flush(subs=[x])
    assert batch.capacity < cap_peak, "second consecutive drain shrinks"
    assert broker.batch_shrinks == 1
    assert broker.stats[-1].batch_shrinks == 1
    assert broker.stats[-1].batch_grows >= 1

    # the decayed batch still drains correctly: Y's flush output equals
    # eager evaluation of the same composed batch by the seed engine
    from repro.core import IrapEngine
    from repro.core.propagation import ChangesetBatch

    d_ref = Dictionary()
    tau_ref = d_ref.encode_triples(
        [("e:1", A, "c:Athlete"), ("e:1", "p:goals", "10"), ("e:2", A, "c:Team")]
    )
    ref_stream = [
        (z, _burst_rows(d_ref, 8, 8, seed=1)),
        (z, _burst_rows(d_ref, 200, 24, seed=2)),
        (z, _burst_rows(d_ref, 4, 4, seed=3)),
        (z, _burst_rows(d_ref, 4, 4, seed=4)),
    ]
    comp = ChangesetBatch.fresh(*ref_stream[0], 1)
    for i, cs in enumerate(ref_stream[1:], start=2):
        comp.extend(*cs, i)
    engine = IrapEngine(d_ref)
    ref_sub = engine.register_interest(
        _exprs()[1], CAPS, initial_target=tau_ref
    )
    want = ref_sub.apply(*comp.arrays())
    got = broker.flush()[list(broker.subs).index(y)]
    for field in ("r", "r_i", "r_prime", "a", "a_i"):
        assert np.array_equal(
            np.asarray(getattr(got, field).spo),
            np.asarray(getattr(want, field).spo),
        ), field


def test_batch_decay_streak_resets_on_refill():
    """A well-filled check between two under-filled ones resets the streak:
    one burst never thrashes the capacity down."""
    from repro.core.propagation import ChangesetBatch

    d, _ = _universe()
    batch = ChangesetBatch.fresh(
        np.zeros((0, 3), np.int32), _burst_rows(d, 8, 8, seed=1), 1
    )
    batch.extend(np.zeros((0, 3), np.int32), _burst_rows(d, 200, 24, seed=2), 2)
    cap = batch.capacity
    assert cap >= 256
    assert not batch.maybe_decay(patience=2)  # arms the streak
    # refill above half: streak resets
    batch.extend(
        np.zeros((0, 3), np.int32),
        d.encode_triples(
            [(f"e:{i}", "p:fill", str(i)) for i in range(cap // 2 + 8)]
        ),
        3,
    )
    assert not batch.maybe_decay(patience=2)
    assert batch._decay_streak == 0
