"""Device-resident deferred evaluation == the host-round-trip baseline.

The PR 2 deferred path pulled every composed batch device→host and
re-uploaded it per fire, running one sequential pass per frontier. The
device-resident path consumes the batches' sorted device stores directly
and stacks same-shape cohorts across frontiers into one executable call.
Outputs (and all replica state) must stay bit-identical between the two —
and therefore to eager evaluation of the composed batches, which
tests/test_broker_scheduling.py pins against the round-trip path.
"""
import numpy as np
import pytest

from repro.core import (
    Broker,
    Dictionary,
    InterestExpr,
    PushPolicy,
    StepCapacities,
)

A = "rdf:type"
CAPS = StepCapacities(n_removed=16, n_added=16, tau=64, rho=64, pulls=32)


def _exprs():
    return [
        InterestExpr.parse(
            "g", "t0", bgp=[("?a", A, "c:Athlete"), ("?a", "p:goals", "?v")]
        ),
        InterestExpr.parse(
            "g", "t1", bgp=[("?a", A, "c:Team"), ("?a", "p:rank", "?v")]
        ),
        InterestExpr.parse("g", "t2", bgp=[("?a", "p:goals", "?v")]),
    ]


def _universe():
    d = Dictionary()
    tau0 = d.encode_triples(
        [
            ("e:1", A, "c:Athlete"),
            ("e:1", "p:goals", "10"),
            ("e:2", A, "c:Team"),
        ]
    )
    return d, tau0


def _stream(d, n, seed=0):
    rng = np.random.default_rng(seed)

    def rows(k):
        out = set()
        for _ in range(k):
            e = f"e:{rng.integers(0, 9)}"
            kind = rng.integers(0, 4)
            if kind == 0:
                out.add((e, A, f"c:{['Athlete', 'Team'][rng.integers(2)]}"))
            elif kind == 1:
                out.add((e, "p:goals", str(int(rng.integers(0, 30)))))
            elif kind == 2:
                out.add((e, "p:rank", str(int(rng.integers(0, 5)))))
            else:
                out.add((e, "p:noise", f"o{rng.integers(0, 6)}"))
        return d.encode_triples(sorted(out))

    return [
        (rows(int(rng.integers(0, 5))), rows(int(rng.integers(1, 7))))
        for _ in range(n)
    ]


def _twin_brokers(d, tau0, policies):
    """Two brokers over one dictionary: device-resident vs round-trip."""
    dev = Broker(d, deferred_device_resident=True)
    rtt = Broker(d, deferred_device_resident=False)
    exprs = _exprs()
    for i, pol in enumerate(policies):
        expr = exprs[i % len(exprs)]
        dev.subscribe(expr, CAPS, initial_target=tau0, policy=pol)
        rtt.subscribe(expr, CAPS, initial_target=tau0, policy=pol)
    return dev, rtt


def assert_results_identical(got, want, label):
    assert len(got) == len(want), label
    for k, (g, w) in enumerate(zip(got, want)):
        assert (g is None) == (w is None), (label, k)
        if g is None:
            continue
        for field in ("r", "r_i", "r_prime", "a", "a_i"):
            gf, wf = getattr(g, field), getattr(w, field)
            assert np.array_equal(
                np.asarray(gf.spo), np.asarray(wf.spo)
            ), (label, k, field)
            assert int(gf.n) == int(wf.n), (label, k, field)


def assert_states_identical(dev, rtt, label):
    for k, (sd, sr) in enumerate(zip(dev.subs, rtt.subs)):
        assert np.array_equal(
            np.asarray(sd.tau.spo), np.asarray(sr.tau.spo)
        ), (label, k, "tau")
        assert np.array_equal(
            np.asarray(sd.rho.spo), np.asarray(sr.rho.spo)
        ), (label, k, "rho")
        assert sd.since == sr.since, (label, k)


def test_device_resident_matches_round_trip_golden():
    """Mixed cadences (eager / every-2 / every-3) through both paths stay
    bit-identical step by step, and a multi-frontier flush stacks the
    same-shape cohorts into fewer passes than the sequential baseline."""
    d, tau0 = _universe()
    dev, rtt = _twin_brokers(
        d,
        tau0,
        [
            PushPolicy(),  # eager
            PushPolicy.every(2),
            PushPolicy.every(3),
            PushPolicy.every(3),  # same shape as sub 0 family, slow lane
        ],
    )
    for i, cs in enumerate(_stream(d, 5, seed=1)):
        got = dev.process_changeset(*cs)
        want = rtt.process_changeset(*cs)
        assert_results_identical(got, want, ("step", i))
        assert_states_identical(dev, rtt, ("step", i))

    # leave two distinct frontiers pending, then drain both paths at once
    got = dev.flush()
    want = rtt.flush()
    assert_results_identical(got, want, "flush")
    assert_states_identical(dev, rtt, "flush")
    if dev.stats and rtt.stats:
        dev_passes = dev.stats[-1].n_cohort_passes
        rtt_passes = rtt.stats[-1].n_cohort_passes
        assert dev_passes <= rtt_passes

    # nothing pending: both flushes are no-ops
    assert dev.flush() == [None] * len(dev.subs)
    assert rtt.flush() == [None] * len(rtt.subs)


def test_multi_frontier_flush_stacks_same_shape_cohorts():
    """Two same-shape subscribers stuck at different frontiers drain in ONE
    stacked cohort pass on the device-resident path (two sequentially on
    the baseline), with identical outputs."""
    d, tau0 = _universe()
    expr = _exprs()[0]
    # pre-encode the stream so the dictionary (and with it id_capacity,
    # part of the cohort key) is identical for both subscriptions
    stream = _stream(d, 4, seed=2)
    dev = Broker(d, deferred_device_resident=True)
    rtt = Broker(d, deferred_device_resident=False)
    for b in (dev, rtt):
        b.subscribe(
            expr, CAPS, initial_target=tau0, policy=PushPolicy.max_staleness(1e9)
        )
    for b in (dev, rtt):
        b.process_changeset(*stream[0])
        # second subscriber arrives mid-stream: its frontier starts later
        b.subscribe(
            expr, CAPS, initial_target=tau0, policy=PushPolicy.max_staleness(1e9)
        )
        for cs in stream[1:]:
            b.process_changeset(*cs)

    got, want = dev.flush(), rtt.flush()
    assert_results_identical(got, want, "stacked flush")
    assert_states_identical(dev, rtt, "stacked flush")
    # both subscribers share one shape cohort: the stacked path folds the
    # two frontiers into a single executable call
    assert dev.stats[-1].n_cohort_passes == 1
    assert rtt.stats[-1].n_cohort_passes == 2


def test_device_resident_property_random_streams():
    """Hypothesis sweep: random policies + random streams stay bit-identical
    between the device-resident and round-trip paths, including flushes."""
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
    )
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @given(
        seed=st.integers(0, 2**16),
        ks=st.lists(st.integers(1, 4), min_size=2, max_size=4),
        n_steps=st.integers(2, 6),
    )
    @settings(
        max_examples=10,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def prop(seed, ks, n_steps):
        d, tau0 = _universe()
        dev, rtt = _twin_brokers(
            d, tau0, [PushPolicy.every(k) for k in ks]
        )
        for i, cs in enumerate(_stream(d, n_steps, seed=seed)):
            got = dev.process_changeset(*cs)
            want = rtt.process_changeset(*cs)
            assert_results_identical(got, want, ("step", i))
        got, want = dev.flush(), rtt.flush()
        assert_results_identical(got, want, "flush")
        assert_states_identical(dev, rtt, "final")

    prop()


# ---------------------------------------------------------------------------
# delta-encoded frontier chains
# ---------------------------------------------------------------------------


def test_delta_chain_matches_stacked_golden():
    """The delta-chain flush (default) stays bit-identical to the PR 3
    stacked pass (delta_frontiers=False) AND the PR 2 round-trip baseline
    across mixed cadences, and its multi-frontier flushes match each
    distinct D row once (rows_matched == rows_distinct) where the stacked
    pass re-matches the shared suffix once per frontier."""
    d, tau0 = _universe()
    policies = [
        PushPolicy.max_staleness(1e9),
        PushPolicy.max_staleness(1e9),
        PushPolicy.every(3),
        PushPolicy.every(2),
    ]
    exprs = _exprs()
    brokers = {
        "delta": Broker(d, deferred_device_resident=True),
        "stacked": Broker(
            d, deferred_device_resident=True, delta_frontiers=False
        ),
        "roundtrip": Broker(d, deferred_device_resident=False),
    }
    assert brokers["delta"].delta_frontiers  # delta is the default
    subs = {}
    for name, b in brokers.items():
        subs[name] = [
            b.subscribe(exprs[i % len(exprs)], CAPS, initial_target=tau0,
                        policy=pol)
            for i, pol in enumerate(policies)
        ]
    stream = _stream(d, 6, seed=7)
    for i, cs in enumerate(stream[:3]):
        got = {n: b.process_changeset(*cs) for n, b in brokers.items()}
        assert_results_identical(got["delta"], got["stacked"], ("step", i))
        assert_results_identical(got["delta"], got["roundtrip"], ("step", i))
    # stagger: drain the first slow subscriber early, then keep feeding so
    # the final flush drains >= 2 overlapping frontiers
    for n, b in brokers.items():
        b.flush(subs=[subs[n][0]])
    for i, cs in enumerate(stream[3:]):
        got = {n: b.process_changeset(*cs) for n, b in brokers.items()}
        assert_results_identical(got["delta"], got["stacked"], ("step2", i))
    flushed = {n: b.flush() for n, b in brokers.items()}
    assert_results_identical(flushed["delta"], flushed["stacked"], "flush")
    assert_results_identical(flushed["delta"], flushed["roundtrip"], "flush")
    assert_states_identical(brokers["delta"], brokers["stacked"], "final")
    assert_states_identical(brokers["delta"], brokers["roundtrip"], "final")

    # dedup efficacy is observable: the delta broker's match volume equals
    # its distinct-row volume, and never exceeds the stacked broker's
    st_d = brokers["delta"].stats[-1]
    st_s = brokers["stacked"].stats[-1]
    assert st_d.rows_matched == st_d.rows_distinct
    assert st_s.rows_matched >= st_d.rows_matched
    assert brokers["delta"].rows_matched == brokers["delta"].rows_distinct
    assert brokers["stacked"].rows_matched >= brokers["stacked"].rows_distinct


def test_delta_chain_nonmonotone_add_remove_readd_golden():
    """A triple added, removed, then re-added across fired frontiers (the
    non-monotone composition case) flushes bit-identically to eager seed
    evaluation of each subscriber's composed batch."""
    from repro.core import IrapEngine
    from repro.core.propagation import ChangesetBatch

    d, tau0 = _universe()
    expr = _exprs()[2]  # ("?a", "p:goals", "?v") — matches T directly
    t_add = d.encode_triples([("e:7", "p:goals", "99")])
    noise = d.encode_triples([("e:8", "p:noise", "o1")])
    z = np.zeros((0, 3), np.int32)
    # cs1 adds T (+ a real D row), cs2 removes T, cs3 re-adds T: frontier
    # [2..3] composes to <{T}, {T}>, frontier [1..3] to <{T, D1}, {T}> —
    # T's A-membership flips between what the two frontiers absorbed
    d1 = d.encode_triples([("e:1", "p:goals", "10")])
    cs = [(d1, t_add), (t_add, noise), (z, t_add)]

    broker = Broker(d)
    pol = PushPolicy.max_staleness(1e9)
    a = broker.subscribe(expr, CAPS, initial_target=tau0, policy=pol)
    b = broker.subscribe(expr, CAPS, initial_target=tau0, policy=pol)

    broker.process_changeset(*cs[0])
    broker.flush(subs=[a])  # a's frontier advances past cs1
    broker.process_changeset(*cs[1])
    broker.process_changeset(*cs[2])
    out = broker.flush()  # drains two overlapping frontiers at once
    assert broker.stats[-1].rows_matched == broker.stats[-1].rows_distinct

    d_ref = Dictionary()
    tau_ref = d_ref.encode_triples(
        [("e:1", A, "c:Athlete"), ("e:1", "p:goals", "10"),
         ("e:2", A, "c:Team")]
    )
    t_ref = d_ref.encode_triples([("e:7", "p:goals", "99")])
    noise_ref = d_ref.encode_triples([("e:8", "p:noise", "o1")])
    d1_ref = d_ref.encode_triples([("e:1", "p:goals", "10")])
    cs_ref = [(d1_ref, t_ref), (t_ref, noise_ref), (z, t_ref)]
    engine = IrapEngine(d_ref)
    ref_a = engine.register_interest(expr, CAPS, initial_target=tau_ref)
    ref_b = engine.register_interest(expr, CAPS, initial_target=tau_ref)
    ref_a.apply(*cs_ref[0])  # a consumed cs1 at the early flush
    comp_a = ChangesetBatch.fresh(*cs_ref[1], 2)
    comp_a.extend(*cs_ref[2], 3)
    comp_b = ChangesetBatch.fresh(*cs_ref[0], 1)
    comp_b.extend(*cs_ref[1], 2)
    comp_b.extend(*cs_ref[2], 3)
    want_a = ref_a.apply(*comp_a.arrays())
    want_b = ref_b.apply(*comp_b.arrays())
    for got, want, label in ((out[0], want_a, "a"), (out[1], want_b, "b")):
        for field in ("r", "r_i", "r_prime", "a", "a_i"):
            assert np.array_equal(
                np.asarray(getattr(got, field).spo),
                np.asarray(getattr(want, field).spo),
            ), (label, field)
    for sub, ref in ((a, ref_a), (b, ref_b)):
        assert np.array_equal(np.asarray(sub.tau.spo), np.asarray(ref.tau.spo))
        assert np.array_equal(np.asarray(sub.rho.spo), np.asarray(ref.rho.spo))


def test_delta_chain_nonmonotone_property():
    """Hypothesis sweep over tiny-pool streams (heavy add/remove/re-add
    churn of the same triples across frontiers): delta-chain flushes stay
    bit-identical to the stacked pass, step by step and at flush."""
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
    )
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @given(
        seed=st.integers(0, 2**16),
        ks=st.lists(st.integers(1, 4), min_size=2, max_size=4),
        n_steps=st.integers(3, 7),
    )
    @settings(
        max_examples=8,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def prop(seed, ks, n_steps):
        rng = np.random.default_rng(seed)
        d, tau0 = _universe()
        # 4-triple pool: the same triples keep entering/leaving D and A,
        # flipping membership between overlapping frontiers
        pool = [("e:1", "p:goals", "10"), ("e:2", "p:goals", "11"),
                ("e:1", A, "c:Athlete"), ("e:3", "p:rank", "2")]

        def pick(k):
            if k == 0:
                return np.zeros((0, 3), np.int32)
            idx = sorted(set(rng.integers(0, len(pool), size=k).tolist()))
            return d.encode_triples([pool[i] for i in idx])

        delta = Broker(d, deferred_device_resident=True)
        stacked = Broker(
            d, deferred_device_resident=True, delta_frontiers=False
        )
        exprs = _exprs()
        for i, k in enumerate(ks):
            for b in (delta, stacked):
                b.subscribe(
                    exprs[i % len(exprs)], CAPS, initial_target=tau0,
                    policy=PushPolicy.every(k),
                )
        for i in range(n_steps):
            cs = (pick(int(rng.integers(0, 3))), pick(int(rng.integers(0, 4))))
            got = delta.process_changeset(*cs)
            want = stacked.process_changeset(*cs)
            assert_results_identical(got, want, ("step", i))
        got, want = delta.flush(), stacked.flush()
        assert_results_identical(got, want, "flush")
        assert_states_identical(delta, stacked, "final")

    prop()


# ---------------------------------------------------------------------------
# flush fast paths
# ---------------------------------------------------------------------------


def test_flush_fast_paths_no_fire_and_empty_batches():
    """No pending work, all-deferred policies, and empty composed batches
    all skip statics/executables entirely: zero cohort passes, zero
    compiles."""
    d, tau0 = _universe()
    broker = Broker(d)
    z = np.zeros((0, 3), np.int32)
    slow = broker.subscribe(
        _exprs()[0], CAPS, initial_target=tau0, policy=PushPolicy.every(100)
    )
    eager = broker.subscribe(
        _exprs()[1], CAPS, initial_target=tau0, policy=PushPolicy()
    )

    # nothing pending: flush is a no-op that touches no executables
    assert broker.flush() == [None, None]
    assert broker.rejit_count == 0 and not broker._exec_cache
    assert len(broker.stats) == 0

    # an all-empty changeset: the eager policy fires but the composed
    # batch is empty — canonical empty outputs, no cohort passes
    outs = broker.process_changeset(z, z)
    assert outs[0] is None  # slow subscriber deferred
    assert outs[1] is not None
    for field in ("r", "r_i", "r_prime", "a", "a_i"):
        assert int(getattr(outs[1], field).n) == 0, field
    assert not bool(outs[1].overflow)
    assert broker.stats[-1].n_cohort_passes == 0
    assert broker.rejit_count == 0 and not broker._exec_cache

    # the slow subscriber's pending batch is empty too: flush drains it
    # through the same fast path and the batch is garbage-collected
    outs = broker.flush()
    assert outs[0] is not None and int(outs[0].r.n) == 0
    assert broker.stats[-1].n_cohort_passes == 0
    assert broker.rejit_count == 0 and not broker._exec_cache
    assert not broker._batches
    assert slow.since == eager.since == broker._last_cid + 1

    # a real changeset afterwards still evaluates normally
    cs = (z, d.encode_triples([("e:1", "p:goals", "77")]))
    outs = broker.process_changeset(*cs)
    assert broker.stats[-1].n_cohort_passes >= 1
    assert int(outs[1].a.n) >= 0  # evaluated, not fast-pathed


def test_empty_batch_fast_path_matches_roundtrip():
    """Both residency modes take the same empty-batch fast path, so their
    results and replica states stay bit-identical around empty fires."""
    d, tau0 = _universe()
    dev, rtt = _twin_brokers(
        d, tau0, [PushPolicy(), PushPolicy.every(2)]
    )
    z = np.zeros((0, 3), np.int32)
    stream = [(z, z), (z, d.encode_triples([("e:1", "p:goals", "31")])),
              (z, z), (z, z)]
    for i, cs in enumerate(stream):
        got = dev.process_changeset(*cs)
        want = rtt.process_changeset(*cs)
        assert_results_identical(got, want, ("step", i))
        assert_states_identical(dev, rtt, ("step", i))
    got, want = dev.flush(), rtt.flush()
    assert_results_identical(got, want, "flush")
    assert_states_identical(dev, rtt, "flush")


def _burst_rows(d, n_raw, n_distinct, seed=0):
    """n_raw triples drawn from an n_distinct-triple pool (duplicate-heavy:
    raw rows force capacity growth, composed rows stay small)."""
    rng = np.random.default_rng(seed)
    pool = [
        (f"e:{i % 50}", "p:goals", str(1000 + i)) for i in range(n_distinct)
    ]
    picks = [pool[rng.integers(0, n_distinct)] for _ in range(n_raw)]
    return d.encode_triples(picks)


def test_batch_capacity_decay():
    """A deferred frontier that grew through a duplicate-heavy burst decays
    back to a smaller pow2 bucket after `decay_patience` consecutive drains,
    and BrokerStats exposes the grow/shrink counts."""
    d, tau0 = _universe()
    broker = Broker(d, decay_patience=2)
    expr = _exprs()[0]
    # X is drained explicitly every round; Y defers forever, so its batch
    # survives every drain and is the decay candidate
    x = broker.subscribe(
        expr, CAPS, initial_target=tau0, policy=PushPolicy.max_staleness(1e9)
    )
    y = broker.subscribe(
        _exprs()[1], CAPS, initial_target=tau0,
        policy=PushPolicy.max_staleness(1e9),
    )
    z = np.zeros((0, 3), np.int32)

    # small first changeset: the shared batch starts at the 64-row floor
    broker.process_changeset(z, _burst_rows(d, 8, 8, seed=1))
    # duplicate-heavy burst: 200 raw rows force the pow2 bucket up, but the
    # composed distinct rows stay far below half the new allocation
    broker.process_changeset(z, _burst_rows(d, 200, 24, seed=2))
    batch = next(iter(broker._batches.values()))
    assert batch.capacity >= 256
    assert broker.batch_grows >= 1
    cap_peak = batch.capacity

    # each explicit drain of X is one decay check on Y's surviving batch;
    # patience=2 means the first check only arms the streak
    broker.process_changeset(z, _burst_rows(d, 4, 4, seed=3))
    broker.flush(subs=[x])
    assert batch.capacity == cap_peak and broker.batch_shrinks == 0
    broker.process_changeset(z, _burst_rows(d, 4, 4, seed=4))
    broker.flush(subs=[x])
    assert batch.capacity < cap_peak, "second consecutive drain shrinks"
    assert broker.batch_shrinks == 1
    assert broker.stats[-1].batch_shrinks == 1
    assert broker.stats[-1].batch_grows >= 1

    # the decayed batch still drains correctly: Y's flush output equals
    # eager evaluation of the same composed batch by the seed engine
    from repro.core import IrapEngine
    from repro.core.propagation import ChangesetBatch

    d_ref = Dictionary()
    tau_ref = d_ref.encode_triples(
        [("e:1", A, "c:Athlete"), ("e:1", "p:goals", "10"), ("e:2", A, "c:Team")]
    )
    ref_stream = [
        (z, _burst_rows(d_ref, 8, 8, seed=1)),
        (z, _burst_rows(d_ref, 200, 24, seed=2)),
        (z, _burst_rows(d_ref, 4, 4, seed=3)),
        (z, _burst_rows(d_ref, 4, 4, seed=4)),
    ]
    comp = ChangesetBatch.fresh(*ref_stream[0], 1)
    for i, cs in enumerate(ref_stream[1:], start=2):
        comp.extend(*cs, i)
    engine = IrapEngine(d_ref)
    ref_sub = engine.register_interest(
        _exprs()[1], CAPS, initial_target=tau_ref
    )
    want = ref_sub.apply(*comp.arrays())
    got = broker.flush()[list(broker.subs).index(y)]
    for field in ("r", "r_i", "r_prime", "a", "a_i"):
        assert np.array_equal(
            np.asarray(getattr(got, field).spo),
            np.asarray(getattr(want, field).spo),
        ), field


def test_batch_decay_streak_resets_on_refill():
    """A well-filled check between two under-filled ones resets the streak:
    one burst never thrashes the capacity down."""
    from repro.core.propagation import ChangesetBatch

    d, _ = _universe()
    batch = ChangesetBatch.fresh(
        np.zeros((0, 3), np.int32), _burst_rows(d, 8, 8, seed=1), 1
    )
    batch.extend(np.zeros((0, 3), np.int32), _burst_rows(d, 200, 24, seed=2), 2)
    cap = batch.capacity
    assert cap >= 256
    assert not batch.maybe_decay(patience=2)  # arms the streak
    # refill above half: streak resets
    batch.extend(
        np.zeros((0, 3), np.int32),
        d.encode_triples(
            [(f"e:{i}", "p:fill", str(i)) for i in range(cap // 2 + 8)]
        ),
        3,
    )
    assert not batch.maybe_decay(patience=2)
    assert batch._decay_streak == 0
