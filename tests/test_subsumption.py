"""Golden tests for the interest-subsumption lattice.

Covers the three layers added for the distinct-interest broker path:

- ``canonicalize_expr``: equal keys for pattern reorderings / bijective
  variable renamings, distinct keys for genuinely different interests;
- ``SubsumptionBank``: exact dedup onto real and virtual lanes, containment
  registration (constant-under-variable rows become refined virtual lanes),
  parent pinning, removal, and total-compaction remaps;
- ``lane_refine``: the residual-refinement op equals a full bank pass over
  the materialized child rows, for the jnp oracle, the XLA fallback, and
  the Pallas kernel in interpret mode;
- ``Broker(subsume_interests=...)``: lattice-on output is bit-identical to
  lattice-off and to the per-interest seed step, while evaluating only
  distinct interests (stats goldens), including auto-join and churn.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (
    Broker,
    Dictionary,
    InterestExpr,
    StepCapacities,
    make_interest_step,
)
from repro.core.interest import (
    REFINE_BASE,
    SubsumptionBank,
    canonicalize_expr,
    compile_interest,
    residual_of,
    row_subsumes,
)
from repro.core.triples import from_numpy, to_numpy
from repro.kernels import ops, ref

WC = -1
E = InterestExpr.parse


# ---------------------------------------------------------------------------
# canonicalizer
# ---------------------------------------------------------------------------

def _key(expr):
    return canonicalize_expr(expr)[1]


def test_canonical_key_invariant_under_renaming_and_reorder():
    base = E("g", "t", bgp=[("?a", "type", "Athlete"), ("?a", "goals", "?g")])
    renamed = E("g", "t",
                bgp=[("?x", "type", "Athlete"), ("?x", "goals", "?y")])
    reordered = E("g", "t",
                  bgp=[("?q", "goals", "?r"), ("?q", "type", "Athlete")])
    assert _key(base) == _key(renamed) == _key(reordered)
    # the canonical *expression* is also identical, so compiled plans match
    d = Dictionary()
    for t in ("type", "goals", "Athlete"):
        d.encode_term(t)
    plans = [compile_interest(canonicalize_expr(e)[0], d)
             for e in (base, renamed, reordered)]
    for p in plans[1:]:
        np.testing.assert_array_equal(p.patterns, plans[0].patterns)


def test_canonical_key_separates_distinct_interests():
    a = E("g", "t", bgp=[("?a", "goals", "?g")])
    assert _key(a) != _key(E("g", "t2", bgp=[("?a", "goals", "?g")]))
    assert _key(a) != _key(E("g2", "t", bgp=[("?a", "goals", "?g")]))
    assert _key(a) != _key(E("g", "t", bgp=[("?a", "type", "?g")]))
    assert _key(a) != _key(E("g", "t", bgp=[("s0", "goals", "?g")]))
    # variable-join structure is naming-independent but not erased:
    # (?a p ?a) is not (?a p ?b)
    assert _key(E("g", "t", bgp=[("?a", "p", "?a")])) != _key(
        E("g", "t", bgp=[("?a", "p", "?b")])
    )
    # OGP patterns are part of the key
    assert _key(a) != _key(
        E("g", "t", bgp=[("?a", "goals", "?g")], ogp=[("?a", "label", "?l")])
    )


def test_canonical_ogp_renaming_shared_with_bgp():
    a = E("g", "t", bgp=[("?a", "goals", "?g")], ogp=[("?a", "label", "?l")])
    b = E("g", "t", bgp=[("?z", "goals", "?q")], ogp=[("?z", "label", "?w")])
    assert _key(a) == _key(b)


# ---------------------------------------------------------------------------
# containment primitives
# ---------------------------------------------------------------------------

def test_row_subsumes_and_residual():
    parent = (WC, 7, WC)
    child = (3, 7, WC)
    assert row_subsumes(parent, child)
    assert not row_subsumes(child, parent)
    assert row_subsumes(parent, parent)  # non-strict
    assert not row_subsumes((WC, 8, WC), child)
    # residual binds exactly the slots the parent leaves open
    assert residual_of(parent, child) == (3, WC, WC)
    assert residual_of((WC, WC, WC), (3, 7, 5)) == (3, 7, 5)
    assert residual_of(parent, (3, 7, 5)) == (3, WC, 5)
    # child variable under parent variable contributes no residual term
    assert residual_of((WC, 7, WC), (WC, 7, 4)) == (WC, WC, 4)


# ---------------------------------------------------------------------------
# SubsumptionBank
# ---------------------------------------------------------------------------

def _bank_with(dictionary, exprs):
    bank = SubsumptionBank()
    lane_maps = [bank.add_plan(compile_interest(e, dictionary)) for e in exprs]
    return bank, lane_maps


def _dict(*terms):
    d = Dictionary()
    for t in terms:
        d.encode_term(t)
    return d


def test_bank_contained_row_becomes_virtual_lane():
    d = _dict("goals", "s0")
    bank, (lp, lc) = _bank_with(d, [
        E("g", "t", bgp=[("?a", "goals", "?g")]),
        E("g", "t", bgp=[("s0", "goals", "?g")]),
    ])
    assert bank.n_real == 1 and bank.n_virtual == 1
    assert lp[0] < REFINE_BASE and lc[0] >= REFINE_BASE
    parents, residual = bank.refine_arrays()
    slot = lc[0] - REFINE_BASE
    assert parents[slot] == lp[0]
    assert tuple(residual[slot]) == (d.lookup("s0"), WC, WC)
    # extended pattern table materializes the child row after the real block
    ext = bank.patterns_padded()
    np.testing.assert_array_equal(
        ext[bank.resolve_lanes(lc)[0]],
        np.asarray([d.lookup("s0"), d.lookup("goals"), WC], np.int32),
    )
    # word layout: extended width = real width + virtual width
    assert bank.n_words == bank.real_padded().shape[0] // 32 + (
        bank.n_virt_padded // 32
    )


def test_bank_exact_duplicates_share_lanes():
    d = _dict("goals", "s0")
    bank, (lp, lc1, lc2, lp2) = _bank_with(d, [
        E("g", "t", bgp=[("?a", "goals", "?g")]),
        E("g", "t", bgp=[("s0", "goals", "?g")]),
        E("g", "t", bgp=[("s0", "goals", "?x")]),   # same row after compile
        E("g", "t", bgp=[("?z", "goals", "?w")]),
    ])
    assert lc1 == lc2          # virtual row dedup
    assert lp == lp2           # real row dedup
    assert bank.n_real == 1 and bank.n_virtual == 1


def test_bank_parent_choice_prefers_most_bound():
    d = _dict("goals", "s0", "o0")
    # two real rows, neither subsuming the other, both subsuming the child;
    # the 2-bound row must win over the earlier 1-bound row
    bank, (l_obj, l_sp, l_child) = _bank_with(d, [
        E("g", "t", bgp=[("?a", "?p", "o0")]),
        E("g", "t", bgp=[("s0", "goals", "?g")]),
        E("g", "t", bgp=[("s0", "goals", "o0")]),
    ])
    assert bank.n_real == 2
    parents, residual = bank.refine_arrays()
    slot = l_child[0] - REFINE_BASE
    assert parents[slot] == l_sp[0]
    assert tuple(residual[slot]) == (WC, WC, d.lookup("o0"))


def test_bank_depth_one_dag_chains_through_real_row():
    # (?a goals ?g) is itself subsumed by the all-variable row, so it lands
    # on a virtual lane; a deeper child then refines the REAL root directly
    # (virtual rows are never parents — depth-1 DAG)
    d = _dict("goals", "s0")
    bank, (l_any, l_pred, l_child) = _bank_with(d, [
        E("g", "t", bgp=[("?a", "?p", "?g")]),
        E("g", "t", bgp=[("?a", "goals", "?g")]),
        E("g", "t", bgp=[("s0", "goals", "?g")]),
    ])
    assert bank.n_real == 1 and bank.n_virtual == 2
    assert l_pred[0] >= REFINE_BASE and l_child[0] >= REFINE_BASE
    parents, residual = bank.refine_arrays()
    assert parents[l_pred[0] - REFINE_BASE] == l_any[0]
    assert parents[l_child[0] - REFINE_BASE] == l_any[0]
    assert tuple(residual[l_child[0] - REFINE_BASE]) == (
        d.lookup("s0"), d.lookup("goals"), WC
    )


def test_bank_virtual_release_frees_slot_and_parent_pin():
    d = _dict("goals", "s0")
    bank, (lp, lc) = _bank_with(d, [
        E("g", "t", bgp=[("?a", "goals", "?g")]),
        E("g", "t", bgp=[("s0", "goals", "?g")]),
    ])
    # removing the parent's own plan keeps the bank row alive: the virtual
    # row holds a reference on its parent lane
    bank.remove_plan(lp)
    assert bank.n_real == 1 and bank.n_virtual == 1
    assert bank.bank.row_of(lp[0]) is not None
    bank.remove_plan(lc)
    assert bank.n_live == 0
    # double release of a freed virtual lane is an error
    with pytest.raises(ValueError):
        bank.remove_plan(lc)


def test_bank_compact_returns_total_remap():
    d = _dict("goals", "type", "Athlete", "s0", "s1")
    bank, maps = _bank_with(d, [
        E("g", "t", bgp=[("?a", "goals", "?g")]),
        E("g", "t", bgp=[("?a", "type", "Athlete")]),
        E("g", "t", bgp=[("s0", "goals", "?g")]),
        E("g", "t", bgp=[("s1", "goals", "?g")]),
    ])
    rows_before = {
        lane: bank.patterns_padded()[bank.resolve_lanes((lane,))[0]].copy()
        for m in (maps[0], maps[2], maps[3])
        for lane in m
    }
    bank.remove_plan(maps[1])   # tombstone one real row
    bank.remove_plan(maps[2])   # tombstone one virtual row
    del rows_before[maps[2][0]]
    remap = bank.maybe_compact(force=True)
    assert remap is not None
    # total over every surviving encoded lane, and row-preserving
    for lane, row in rows_before.items():
        new = remap[lane]
        np.testing.assert_array_equal(
            bank.patterns_padded()[bank.resolve_lanes((new,))[0]], row
        )
    assert bank.n_real == 1 and bank.n_virtual == 1


# ---------------------------------------------------------------------------
# lane_refine op parity
# ---------------------------------------------------------------------------

def _refine_case(seed, n_rows, n_pat, n_virt, vp):
    rng = np.random.default_rng(seed)
    pats = rng.integers(-1, 5, size=(n_pat, 3)).astype(np.int32)
    spo = rng.integers(0, 5, size=(n_rows, 3)).astype(np.int32)
    spo[rng.random(n_rows) < 0.1] = ref.PAD  # PAD rows match nothing
    parents = np.full((vp,), -1, np.int32)
    residual = np.full((vp, 3), ref.PAD, np.int32)
    slots = rng.choice(vp, size=n_virt, replace=False)
    for v in slots:
        p = rng.integers(0, n_pat)
        parents[v] = p
        # the residual contract: constants only in slots the parent leaves
        # variable (residual_of never binds a parent-bound slot)
        residual[v] = [
            rng.integers(0, 5)
            if pats[p, k] == WC and rng.random() < 0.7 else WC
            for k in range(3)
        ]
    return (jnp.asarray(spo), jnp.asarray(pats), jnp.asarray(parents),
            jnp.asarray(residual))


@pytest.mark.parametrize("seed,n_virt,vp", [
    (0, 5, 32), (1, 20, 32), (2, 40, 64), (3, 1, 32),
])
def test_lane_refine_equals_materialized_children(seed, n_virt, vp):
    """Refined bits == full bank pass over child rows (parent AND residual)."""
    spo, pats, parents, residual = _refine_case(seed, 96, 7, n_virt, vp)
    words = ref.pattern_bitmask_words_ref(spo, pats)
    got = ref.lane_refine_ref(spo, words, parents, residual)
    # materialize child = parent row overwritten by bound residual slots;
    # dead slots use a never-matching row
    children = np.full((vp, 3), ref.PAD, np.int32)
    for v in range(vp):
        p = int(parents[v])
        if p < 0:
            continue
        row = np.asarray(pats[p]).copy()
        for k in range(3):
            if int(residual[v, k]) != WC:
                row[k] = residual[v, k]
        children[v] = row
    want = ref.pattern_bitmask_words_ref(spo, jnp.asarray(children))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed,n_virt,vp", [(4, 12, 32), (5, 40, 64)])
def test_lane_refine_op_matches_oracle(seed, n_virt, vp):
    spo, pats, parents, residual = _refine_case(seed, 80, 6, n_virt, vp)
    words = ref.pattern_bitmask_words_ref(spo, pats)
    want = np.asarray(ref.lane_refine_ref(spo, words, parents, residual))
    xla = ops.lane_refine(spo, words, parents, residual, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(xla), want)
    kern = ops.lane_refine(spo, words, parents, residual, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(kern), want)


def test_lane_refine_empty_virtual_space():
    spo, pats, _, _ = _refine_case(6, 32, 4, 1, 32)
    words = ref.pattern_bitmask_words_ref(spo, pats)
    out = ops.lane_refine(
        spo, words, jnp.zeros((0,), jnp.int32), jnp.zeros((0, 3), jnp.int32)
    )
    assert out.shape == (32, 1)
    assert not np.asarray(out).any()


# ---------------------------------------------------------------------------
# broker golden: lattice-on == lattice-off == seed per-interest oracle
# ---------------------------------------------------------------------------

TERMS = (
    ["type", "goals", "label", "Athlete", "Team"]
    + [f"s{i}" for i in range(6)]
    + [f"o{i}" for i in range(4)]
)
CAPS = StepCapacities(
    n_removed=8, n_added=8, tau=64, rho=32, pulls=64, fanout=4
)
GOLDEN_EXPRS = [
    E("g", "t", bgp=[("?a", "goals", "?g")]),           # parent
    E("g", "t", bgp=[("s0", "goals", "?g")]),           # contained child
    E("g", "t", bgp=[("?x", "goals", "?y")]),           # renamed dup of [0]
    E("g", "t", bgp=[("?a", "type", "Athlete"), ("?a", "goals", "?g")]),
    E("g", "t", bgp=[("?q", "goals", "?r"), ("?q", "type", "Athlete")]),
    E("g", "t", bgp=[("?a", "goals", "?g")]),           # exact dup of [0]
]


def _fresh_dict():
    d = Dictionary()
    for t in TERMS:
        d.encode_term(t)
    return d


def _golden_changesets(n, seed=7):
    d = _fresh_dict()
    rng = np.random.default_rng(seed)
    subj = [d.lookup(f"s{i}") for i in range(6)]
    pred = [d.lookup(x) for x in ("type", "goals", "label")]
    obj = [d.lookup(x) for x in ("Athlete", "Team", "o0", "o1")] + subj[:2]

    def rows(k):
        out = sorted({
            (subj[rng.integers(6)], pred[rng.integers(3)],
             obj[rng.integers(len(obj))])
            for _ in range(k)
        })
        return (np.asarray(out, np.int32) if out
                else np.zeros((0, 3), np.int32))

    return [(rows(4), rows(6)) for _ in range(n)]


def _outs(o):
    if o is None:
        return None
    return tuple(
        to_numpy(getattr(o, f)) for f in ("r", "r_i", "r_prime", "a", "a_i")
    )


def _run_broker(subsume, csets):
    b = Broker(dictionary=_fresh_dict(), subsume_interests=subsume)
    subs = [b.subscribe(e, CAPS) for e in GOLDEN_EXPRS]
    log = [[_outs(o) for o in b.process_changeset(rm, ad)]
           for rm, ad in csets]
    return b, subs, log


def _assert_logs_equal(l1, l0):
    assert len(l1) == len(l0)
    for t, (r1, r0) in enumerate(zip(l1, l0)):
        assert len(r1) == len(r0)
        for k, (a, c) in enumerate(zip(r1, r0)):
            assert (a is None) == (c is None), (t, k)
            if a is None:
                continue
            for f, (x, y) in enumerate(zip(a, c)):
                np.testing.assert_array_equal(x, y, err_msg=f"{t}/{k}/{f}")


def test_broker_lattice_matches_baseline_and_seed():
    csets = _golden_changesets(6)
    b_on, subs_on, log_on = _run_broker(True, csets)
    _, _, log_off = _run_broker(False, csets)
    _assert_logs_equal(log_on, log_off)

    # seed oracle: one make_interest_step per subscription, same caps
    d = _fresh_dict()
    idc = d.id_capacity * CAPS.id_headroom
    for k, expr in enumerate(GOLDEN_EXPRS):
        plan = compile_interest(canonicalize_expr(expr)[0], d)
        step = make_interest_step(plan, id_capacity=idc, caps=CAPS)
        tau = from_numpy(np.zeros((0, 3), np.int32), CAPS.tau)
        rho = from_numpy(np.zeros((0, 3), np.int32), CAPS.rho)
        for t, (rm, ad) in enumerate(csets):
            tau, rho, out = step(
                from_numpy(rm, CAPS.n_removed), from_numpy(ad, CAPS.n_added),
                tau, rho,
            )
            got = log_on[t][k]
            want = _outs(out)
            for f, (x, y) in enumerate(zip(got, want)):
                np.testing.assert_array_equal(x, y, err_msg=f"{t}/{k}/{f}")

    # distinct-interest accounting: exprs 0/2/5 collapse, 3/4 collapse,
    # child rides a virtual lane -> 3 distinct slots serve 6 subscribers
    assert b_on.stats[-1].distinct_interests == 3
    assert b_on.stats[-1].fanout_copies == 6
    # 4 distinct rows overall: (?a goals ?g) shared by exprs 0/2/3/4/5,
    # (?a type Athlete), and the contained (s0 goals ?g) as a virtual lane
    assert b_on.bank.n_real == 2 and b_on.bank.n_virtual == 1


def test_broker_lattice_off_stats_degenerate():
    csets = _golden_changesets(2)
    b_off, _, _ = _run_broker(False, csets)
    assert b_off.stats[-1].distinct_interests == b_off.stats[-1].fanout_copies
    assert b_off.distinct_interests == b_off.fanout_copies


def test_broker_auto_join_and_independence():
    b = Broker(dictionary=_fresh_dict())
    s0 = b.subscribe(GOLDEN_EXPRS[0], CAPS)
    # identical fresh subscription auto-joins s0's lane group
    s1 = b.subscribe(GOLDEN_EXPRS[2], CAPS)
    assert s1.share_tag is s0.share_tag and s1.canon_sig == s0.canon_sig
    # after state has advanced, a newcomer must stay independent (its τ/ρ
    # frontier differs) — a missed collapse, never a wrong one
    for rm, ad in _golden_changesets(2):
        b.process_changeset(rm, ad)
    s2 = b.subscribe(GOLDEN_EXPRS[0], CAPS)
    assert s2.share_tag is not s0.share_tag
    # different policy/capacities never join
    s3 = b.subscribe(
        GOLDEN_EXPRS[0],
        StepCapacities(n_removed=8, n_added=8, tau=128, rho=32, pulls=64,
                       fanout=4),
    )
    assert s3.share_tag is not s0.share_tag


def test_broker_share_index_survives_root_churn():
    b = Broker(dictionary=_fresh_dict())
    s0 = b.subscribe(GOLDEN_EXPRS[0], CAPS)
    s1 = b.subscribe(GOLDEN_EXPRS[2], CAPS)   # joins s0
    b.unsubscribe(s0)
    # s1 is promoted to root; a fresh duplicate joins *its* lineage
    s2 = b.subscribe(GOLDEN_EXPRS[5], CAPS)
    assert s2.share_tag is s1.share_tag
    b.unsubscribe(s1)
    b.unsubscribe(s2)
    assert b._share_index == {}
    # bank was reset; re-subscribing starts a fresh lineage without error
    s3 = b.subscribe(GOLDEN_EXPRS[0], CAPS)
    assert b.bank.n_live == s3.plan.n_total
