"""Substrate tests: checkpoint/restart, fault tolerance, compression, data."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke_config
from repro.data import (
    DBpediaLikeGenerator,
    GeneratorConfig,
    ReplicaTokenPipeline,
    Verbalizer,
)
from repro.core import Dictionary, InterestExpr, IrapEngine, StepCapacities
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import AdamW
from repro.optim.compression import (
    ErrorFeedbackInt8,
    dequantize_int8,
    quantize_int8,
)
from repro.runtime import SimulatedFailure, Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_gc(tmp_path):
    store = CheckpointStore(tmp_path)
    state = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)},
        "opt": {"m": {"w": jnp.zeros((2, 3)), "b": jnp.zeros(3)},
                "step": jnp.int32(7)},
    }
    for s in (10, 20, 30, 40):
        store.save(s, state)
    assert store.latest_step() == 40
    # gc keeps 3
    assert len(list(tmp_path.glob("step_*"))) == 3
    restored, step = store.restore(state)
    assert step == 40
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.arange(6.0).reshape(2, 3)
    )
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_restore_with_resharding(tmp_path):
    """Elastic path: restore onto an explicit (single-device) sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    store = CheckpointStore(tmp_path)
    state = {"params": {"w": jnp.arange(8.0)}}
    store.save(1, state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"params": {"w": NamedSharding(mesh, P(None))}}
    restored, _ = store.restore(state, shardings=sh)
    assert restored["params"]["w"].sharding == sh["params"]["w"]


# ---------------------------------------------------------------------------
# trainer: loss decreases, failure injection + restart resumes
# ---------------------------------------------------------------------------
def _toy_setup(tmp_path, seed=0):
    cfg = get_smoke_config("internlm2-1.8b")
    api = build_model(cfg)
    opt = AdamW(learning_rate=3e-3, max_grad_norm=1.0)

    def init_state():
        params = api.init(jax.random.key(seed))
        return params, opt.init(params)

    rng = np.random.default_rng(0)
    fixed = {
        "tokens": rng.integers(0, cfg.vocab, (4, 16)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (4, 16)).astype(np.int32),
    }

    def data():
        while True:
            yield fixed  # memorizable batch -> loss must fall

    step = make_train_step(api, opt)
    tc = TrainerConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=5)
    return step, init_state, data(), tc


def test_trainer_loss_decreases(tmp_path):
    step, init_state, data, tc = _toy_setup(tmp_path)
    tr = Trainer(step, init_state, data, tc)
    hist = tr.run(25)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.9


def test_failure_injection_and_restart(tmp_path):
    step, init_state, data, tc = _toy_setup(tmp_path)
    tr = Trainer(step, init_state, data, tc)
    with pytest.raises(SimulatedFailure):
        tr.run(30, inject_failure_at=17)
    loss_at_fail = tr.history[-1]["loss"]

    # new trainer process: must resume from step 15 (last ckpt), not step 0
    step2, init_state2, data2, _ = _toy_setup(tmp_path)
    tr2 = Trainer(step2, init_state2, data2, tc)
    assert tr2.step == 15
    hist = tr2.run(10)
    assert hist[0]["step"] == 16
    # resumed trajectory continues converging (not a cold restart)
    assert hist[-1]["loss"] < loss_at_fail * 1.1


def test_straggler_detection(tmp_path):
    step, init_state, data, tc = _toy_setup(tmp_path)
    events = []
    tr = Trainer(
        step, init_state, data, tc, on_straggler=lambda s, dt: events.append(s)
    )

    # wrap the jitted step to inject one slow step
    orig = tr.step_fn
    import time as _t

    def slow_step(p, s, b):
        if tr.step == 14:
            _t.sleep(1.0)
        return orig(p, s, b)

    tr.step_fn = slow_step
    tr.run(20)
    assert tr.straggler_events and tr.straggler_events[0]["step"] == 15
    assert events and events[0] == 15


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_quantize_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.key(0), (257,)) * 3.0
    q, scale = quantize_int8(g)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - g))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_converges_like_uncompressed():
    """EF-int8 AdamW reaches (almost) the same optimum on a quadratic."""
    target = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)

    def loss_fn(p):
        return jnp.sum(jnp.square(p["w"] - target))

    def run(opt):
        params = {"w": jnp.zeros(64, jnp.float32)}
        state = opt.init(params)
        for _ in range(300):
            g = jax.grad(loss_fn)(params)
            params, state, _ = opt.update(g, state, params)
        return float(loss_fn(params))

    base = run(AdamW(learning_rate=3e-2))
    comp = run(ErrorFeedbackInt8(AdamW(learning_rate=3e-2)))
    assert comp < max(base * 3, 1e-2), (base, comp)


# ---------------------------------------------------------------------------
# data plane
# ---------------------------------------------------------------------------
def test_changeset_generator_consistency():
    gen = DBpediaLikeGenerator(GeneratorConfig(
        n_athletes=20, n_places=20, n_other=50, n_teams=5,
        adds_per_changeset=40, removes_per_changeset=15, seed=3))
    dump = gen.initial_dump()
    assert dump.shape[0] > 100
    live = set(gen.current)
    for d_np, a_np in gen.stream(5):
        # removes came from the live set; adds are new
        live = live  # string-level invariants tracked inside generator
        assert d_np.shape[1] == 3 and a_np.shape[1] == 3
        assert a_np.shape[0] > 0
    # determinism under seed
    gen2 = DBpediaLikeGenerator(GeneratorConfig(
        n_athletes=20, n_places=20, n_other=50, n_teams=5,
        adds_per_changeset=40, removes_per_changeset=15, seed=3))
    dump2 = gen2.initial_dump()
    np.testing.assert_array_equal(dump, dump2)


def test_replica_pipeline_end_to_end():
    """Generator -> iRap subscription -> verbalizer -> LM batches."""
    gen = DBpediaLikeGenerator(GeneratorConfig(
        n_athletes=30, n_places=10, n_other=40, n_teams=6,
        adds_per_changeset=30, removes_per_changeset=10, seed=1))
    gen.initial_dump()
    engine = IrapEngine(gen.dict)
    expr = InterestExpr.parse(
        "g", "t",
        bgp=[("?f", "rdf:type", "dbo:SoccerPlayer"),
             ("?f", "foaf:name", "?n"),
             ("?f", "dbo:team", "?t"),
             ("?t", "rdfs:label", "?tn")],
    )
    caps = StepCapacities(n_removed=256, n_added=512, tau=4096, rho=4096,
                          pulls=8192, fanout=8)
    init = gen.slice_for(
        lambda t: t[0].startswith("dbr:Athlete") or t[0].startswith("dbr:Team")
    )
    sub = engine.register_interest(expr, caps, initial_target=init)
    verb = Verbalizer(vocab=997, dictionary=gen.dict)
    pipe = ReplicaTokenPipeline(verb, batch_size=4, seq_len=32)
    for d_np, a_np in gen.stream(2):
        sub.apply(d_np, a_np)
    pipe.refresh(sub.tau)
    batch = next(pipe)
    assert batch["tokens"].shape == (4, 32)
    assert batch["labels"].shape == (4, 32)
    assert batch["tokens"].max() < 997
    assert int(sub.tau.n) > 50
