"""End-to-end behaviour tests for the whole system.

One compact integration flow: evolving source -> interest subscription ->
replica consistency (vs the oracle) -> token pipeline -> one train step ->
checkpoint. Each stage also has its own deeper suite under tests/.
"""
import jax
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke_config
from repro.core import (
    Dictionary,
    InterestExpr,
    IrapEngine,
    StepCapacities,
    to_set,
)
from repro.core.interest import compile_interest
from repro.core.oracle import OracleEvaluator
from repro.data import (
    DBpediaLikeGenerator,
    GeneratorConfig,
    ReplicaTokenPipeline,
    Verbalizer,
)
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import AdamW


def test_end_to_end_system(tmp_path):
    # 1. evolving source + interest subscription
    gen = DBpediaLikeGenerator(GeneratorConfig(
        n_athletes=40, n_places=15, n_other=60, n_teams=8,
        adds_per_changeset=50, removes_per_changeset=20, seed=42))
    gen.initial_dump()
    engine = IrapEngine(gen.dict)
    expr = InterestExpr.parse(
        "g", "t",
        bgp=[("?f", "rdf:type", "dbo:SoccerPlayer"),
             ("?f", "foaf:name", "?n"),
             ("?f", "dbo:team", "?t"),
             ("?t", "rdfs:label", "?tn")],
    )
    caps = StepCapacities(n_removed=256, n_added=512, tau=8192, rho=8192,
                          pulls=8192, fanout=8, dedup_candidates=1024)
    sub = engine.register_interest(
        expr, caps,
        initial_target=gen.slice_for(
            lambda t: t[0].startswith(("dbr:Athlete", "dbr:Team"))),
    )

    # 2. stream changesets; replica semantics checked vs the oracle
    plan = compile_interest(expr, gen.dict)
    orc = OracleEvaluator(plan)
    for i, (d_np, a_np) in enumerate(gen.stream(3)):
        tau_before = to_set(sub.tau)
        rho_before = to_set(sub.rho)
        sub.apply(d_np, a_np)
        o = orc.step(
            {tuple(map(int, r)) for r in d_np},
            {tuple(map(int, r)) for r in a_np},
            tau_before,
            rho_before,
        )
        assert to_set(sub.tau) == o["tau1"], f"changeset {i} τ mismatch"
        assert to_set(sub.rho) == o["rho1"], f"changeset {i} ρ mismatch"
    assert int(sub.tau.n) > 50

    # 3. replica feeds the LM pipeline; one real optimizer step runs
    verb = Verbalizer(vocab=97, dictionary=gen.dict)
    pipe = ReplicaTokenPipeline(verb, batch_size=2, seq_len=16)
    pipe.refresh(sub.tau)
    batch = next(pipe)

    cfg = get_smoke_config("internlm2-1.8b")
    api = build_model(cfg)
    opt = AdamW(learning_rate=1e-3)
    params = api.init(jax.random.key(0))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(api, opt))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))

    # 4. checkpoint round-trip of the trained state
    store = CheckpointStore(tmp_path)
    store.save(1, {"params": params2, "opt": opt_state2})
    restored, step_no = store.restore({"params": params2, "opt": opt_state2})
    assert step_no == 1
    assert all(
        np.all(np.isfinite(np.asarray(l)))
        for l in jax.tree.leaves(restored["params"])
    )
