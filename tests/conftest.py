"""Shared test configuration.

``hypothesis`` is an *optional* dev dependency (see requirements-dev.txt):
the property-test modules (test_kernels.py, test_properties.py,
test_broker_properties.py) guard themselves with
``pytest.importorskip("hypothesis")`` at import time, so without it they are
reported as **skipped** instead of failing collection.

This conftest additionally puts ``src/`` on ``sys.path`` so
``python -m pytest`` works from the repo root even without
``PYTHONPATH=src``.
"""
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
