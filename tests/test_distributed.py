"""Distributed (shard_map) interest evaluation == single-device evaluation.

Runs in a subprocess with 8 forced host devices so the main test process
keeps its single-device jax config (the dry-run owns the 512-device setup).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np

    from repro.core import Dictionary, InterestExpr, from_numpy
    from repro.core.distributed import (
        gather_result_sets,
        make_distributed_evaluator,
        make_mesh_compat,
        partition_rows,
        prepare_target_shards,
    )
    from repro.core.evaluation import build_index, make_side_evaluator
    from repro.core.interest import compile_interest
    from repro.core.triples import PAD

    N_SHARDS = 4
    mesh = make_mesh_compat((N_SHARDS,), ("data",))

    d = Dictionary()
    for t in ([f"s{i}" for i in range(12)] + ["type", "p0", "p1", "goals",
              "label", "Athlete"] + [f"o{i}" for i in range(8)]):
        d.encode_term(t)
    R = d.id_capacity

    plans = {
        "star": InterestExpr.parse("g", "t",
            bgp=[("?a", "type", "Athlete"), ("?a", "goals", "?g")],
            ogp=[("?a", "p0", "?h")]),
        "football": InterestExpr.parse("g", "t",
            bgp=[("?f", "type", "Athlete"), ("?f", "p1", "?t"),
                 ("?t", "label", "?n")]),
    }

    SUBJ = [d.lookup(f"s{i}") for i in range(12)]
    PRED = [d.lookup(x) for x in ("type", "p0", "p1", "goals", "label")]
    OBJ = [d.lookup(x) for x in ("Athlete", "o0", "o1")] + SUBJ[:6]

    rng = np.random.default_rng(0)
    M_CAP, T_CAP, K = 32, 64, 8

    def rand_rows(n):
        return np.stack([
            rng.choice(SUBJ, n), rng.choice(PRED, n), rng.choice(OBJ, n)
        ], axis=1).astype(np.int32)

    n_cases = 0
    for name, expr in plans.items():
        plan = compile_interest(expr, d)
        local_ev = make_side_evaluator(
            plan, id_capacity=R, fanout=K, out_capacity=4 * M_CAP,
            pull_capacity=4096)
        dist_ev = make_distributed_evaluator(
            plan, mesh, id_capacity=R, fanout=K,
            out_capacity=4 * M_CAP, pull_capacity=4096)
        for trial in range(6):
            m_rows = np.unique(rand_rows(rng.integers(1, 24)), axis=0)
            tau_rows = np.unique(rand_rows(rng.integers(1, 40)), axis=0)

            m_store = from_numpy(m_rows, M_CAP * N_SHARDS)
            tau_store = from_numpy(tau_rows, T_CAP)
            ref = local_ev(m_store, build_index(tau_store))
            from repro.core import to_set
            want = (to_set(ref.interesting), to_set(ref.potential),
                    to_set(ref.pulls))

            m_sh, m_ovf = partition_rows(m_rows, N_SHARDS, key_col=0, cap=M_CAP)
            spo_sh, ops_sh, t_ovf = prepare_target_shards(
                tau_rows, N_SHARDS, T_CAP)
            assert not m_ovf.any() and not t_ovf.any()
            res = dist_ev(jax.numpy.asarray(m_sh), jax.numpy.asarray(spo_sh),
                          jax.numpy.asarray(ops_sh))
            got = gather_result_sets(res, partition_overflow=m_ovf | t_ovf)
            assert got[0] == want[0], (name, trial, "interesting", got[0], want[0])
            assert got[1] == want[1], (name, trial, "potential")
            assert got[2] == want[2], (name, trial, "pulls")
            assert got[3] == bool(ref.overflow), (name, trial, "overflow")
            n_cases += 1
    print(f"DISTRIBUTED_EQUIVALENCE_OK cases={n_cases}")
    """
)


def test_partition_rows_overflow_flags():
    """Per-shard overflow comes back as flags, never as an exception."""
    np_mod = pytest.importorskip("numpy")
    from repro.core.distributed import partition_rows, prepare_target_shards
    from repro.core.triples import PAD

    rows = np_mod.stack(
        [
            np_mod.arange(8, dtype=np_mod.int32) * 2,  # all even subjects
            np_mod.ones(8, np_mod.int32),
            np_mod.arange(8, dtype=np_mod.int32),
        ],
        axis=1,
    )
    shards, overflow = partition_rows(rows, n_shards=2, key_col=0, cap=4)
    assert overflow.tolist() == [True, False]  # shard 0 got all 8 rows
    assert (shards[0, :, 0] != PAD).sum() == 4  # excess rows dropped, not raised
    assert (shards[1, :, 0] == PAD).all()

    spo, ops, t_ovf = prepare_target_shards(rows, n_shards=2, cap=4)
    assert t_ovf.tolist() == [True, False]
    ok_sh, ok_ovf = partition_rows(rows, n_shards=2, key_col=0, cap=8)
    assert not ok_ovf.any()


@pytest.mark.slow
def test_distributed_equals_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "DISTRIBUTED_EQUIVALENCE_OK" in proc.stdout
