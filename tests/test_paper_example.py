"""The paper's running example (Examples 1-9) as an end-to-end fidelity test.

Interest (Example 2): b = { ?a a dbo:Athlete . ?a dbp:goals ?goals . }
                      op = { ?a foaf:homepage ?page . }
Changeset (Example 1, dbp:goals normalized — the paper mixes dbp:/dbo:goals
in its listings but treats them as one predicate in Examples 3-9).

Asserted against the paper:
  Example 3  — candidate generation classes (via bit counts)
  Example 5  — d(): r, r_i, r'
  Example 6  — α(): a, a_i
  Example 7/8— interesting + potentially interesting changesets
  Example 9  — resulting τ and ρ (Listings 1.3 / 1.4)
"""
import numpy as np
import pytest

from repro.core import (
    Dictionary,
    InterestExpr,
    IrapEngine,
    StepCapacities,
    to_set,
)
from repro.core.oracle import OracleEvaluator

A = "rdf:type"  # 'a'


def triples(dictionary, rows):
    return dictionary.encode_triples(rows)


@pytest.fixture()
def setup():
    d = Dictionary()
    expr = InterestExpr.parse(
        source="http://live.dbpedia.org/changesets",
        target="http://localhost:3030/target/sparql",
        bgp=[("?a", A, "dbo:Athlete"), ("?a", "dbp:goals", "?goals")],
        ogp=[("?a", "foaf:homepage", "?page")],
    )

    tau0 = [
        ("dbr:Marcel", A, "dbo:Athlete"),
        ("dbr:Cristiano_Ronaldo", A, "dbo:Athlete"),
        ("dbr:Cristiano_Ronaldo", "dbp:goals", "96"),
        ("dbr:Cristiano_Ronaldo", "foaf:homepage", '"http://cristianoronaldo.com"'),
    ]
    removed = [
        ("dbr:Marcel", "dbp:goals", "1"),
        ("dbr:Marcel", "dbo:team", "dbr:FNFT"),
        ("dbr:Tim%02", "foaf:name", '"Tim Berners-Lee"'),
        ("dbr:Cristiano_Ronaldo", "dbp:goals", "96"),
    ]
    added = [
        ("dbr:Cristiano_Ronaldo", "dbp:goals", "216"),
        ("dbr:Barack_Obama", "foaf:name", '"Barack Obama"'),
        ("dbr:Barack_Obama", "foaf:homepage", '"http://www.barackobama.com/"'),
        ("dbr:Rio_Ferdinand", A, "foaf:Person"),
        ("dbr:Rio_Ferdinand", A, "dbo:Athlete"),
        ("dbr:Rio_Ferdinand", "dbp:goals", "10"),
        ("dbr:Arvid_Smit", A, "dbo:Athlete"),
    ]
    # NOTE: τ holds Ronaldo's goals as dbp:goals (paper uses dbo:goals there —
    # normalized, see module docstring) so the delete of goals-96 matches it.
    return d, expr, tau0, removed, added


def sets_of(d, rows):
    return {tuple(int(x) for x in r) for r in d.encode_triples(rows)}


def test_running_example_engine(setup):
    d, expr, tau0, removed, added = setup
    engine = IrapEngine(d)
    caps = StepCapacities(n_removed=16, n_added=16, tau=64, rho=64, pulls=32)
    sub = engine.register_interest(expr, caps, initial_target=triples(d, tau0))

    d_np = triples(d, removed)
    a_np = triples(d, added)
    out = sub.apply(d_np, a_np)

    # Example 5 — d(i, D)
    assert to_set(out.r) == sets_of(
        d,
        [
            ("dbr:Marcel", "dbp:goals", "1"),
            ("dbr:Cristiano_Ronaldo", "dbp:goals", "96"),
        ],
    )
    assert to_set(out.r_i) == set()
    assert to_set(out.r_prime) == sets_of(
        d,
        [
            ("dbr:Marcel", A, "dbo:Athlete"),
            ("dbr:Cristiano_Ronaldo", A, "dbo:Athlete"),
            (
                "dbr:Cristiano_Ronaldo",
                "foaf:homepage",
                '"http://cristianoronaldo.com"',
            ),
        ],
    )

    # Example 6 — α(i, A ∪ ρ)
    assert to_set(out.a) == sets_of(
        d,
        [
            ("dbr:Cristiano_Ronaldo", "dbp:goals", "216"),
            ("dbr:Cristiano_Ronaldo", A, "dbo:Athlete"),
            (
                "dbr:Cristiano_Ronaldo",
                "foaf:homepage",
                '"http://cristianoronaldo.com"',
            ),
            ("dbr:Rio_Ferdinand", A, "dbo:Athlete"),
            ("dbr:Rio_Ferdinand", "dbp:goals", "10"),
        ],
    )
    assert to_set(out.a_i) == sets_of(
        d,
        [
            ("dbr:Arvid_Smit", A, "dbo:Athlete"),
            (
                "dbr:Barack_Obama",
                "foaf:homepage",
                '"http://www.barackobama.com/"',
            ),
        ],
    )

    # Example 9 / Listing 1.3 — resulting target dataset
    assert to_set(sub.tau) == sets_of(
        d,
        [
            ("dbr:Cristiano_Ronaldo", "dbp:goals", "216"),
            ("dbr:Cristiano_Ronaldo", A, "dbo:Athlete"),
            (
                "dbr:Cristiano_Ronaldo",
                "foaf:homepage",
                '"http://cristianoronaldo.com"',
            ),
            ("dbr:Rio_Ferdinand", A, "dbo:Athlete"),
            ("dbr:Rio_Ferdinand", "dbp:goals", "10"),
        ],
    )
    # Example 8 / Listing 1.4 — potentially interesting dataset
    assert to_set(sub.rho) == sets_of(
        d,
        [
            ("dbr:Arvid_Smit", A, "dbo:Athlete"),
            (
                "dbr:Barack_Obama",
                "foaf:homepage",
                '"http://www.barackobama.com/"',
            ),
            ("dbr:Marcel", A, "dbo:Athlete"),
        ],
    )


def test_running_example_oracle_agrees(setup):
    """The pure-python oracle reproduces the same sets (sanity for the
    property-test reference)."""
    d, expr, tau0, removed, added = setup
    from repro.core.interest import compile_interest

    # encode everything first so the dictionary is complete
    tau_np = triples(d, tau0)
    d_np = triples(d, removed)
    a_np = triples(d, added)
    plan = compile_interest(expr, d)
    orc = OracleEvaluator(plan)
    res = orc.step(
        {tuple(map(int, r)) for r in d_np},
        {tuple(map(int, r)) for r in a_np},
        {tuple(map(int, r)) for r in tau_np},
        set(),
    )
    assert res["r"] == sets_of(
        d,
        [
            ("dbr:Marcel", "dbp:goals", "1"),
            ("dbr:Cristiano_Ronaldo", "dbp:goals", "96"),
        ],
    )
    assert res["rho1"] == sets_of(
        d,
        [
            ("dbr:Arvid_Smit", A, "dbo:Athlete"),
            (
                "dbr:Barack_Obama",
                "foaf:homepage",
                '"http://www.barackobama.com/"',
            ),
            ("dbr:Marcel", A, "dbo:Athlete"),
        ],
    )
    assert res["tau1"] == sets_of(
        d,
        [
            ("dbr:Cristiano_Ronaldo", "dbp:goals", "216"),
            ("dbr:Cristiano_Ronaldo", A, "dbo:Athlete"),
            (
                "dbr:Cristiano_Ronaldo",
                "foaf:homepage",
                '"http://cristianoronaldo.com"',
            ),
            ("dbr:Rio_Ferdinand", A, "dbo:Athlete"),
            ("dbr:Rio_Ferdinand", "dbp:goals", "10"),
        ],
    )


def test_second_changeset_promotes_from_rho(setup):
    """A later changeset adding Arvid's goals promotes his parked ρ triple."""
    d, expr, tau0, removed, added = setup
    engine = IrapEngine(d)
    caps = StepCapacities(n_removed=16, n_added=16, tau=64, rho=64, pulls=32)
    sub = engine.register_interest(expr, caps, initial_target=triples(d, tau0))
    sub.apply(triples(d, removed), triples(d, added))

    out2 = sub.apply(
        np.zeros((0, 3), np.int32),
        triples(d, [("dbr:Arvid_Smit", "dbp:goals", "3")]),
    )
    assert to_set(out2.a) == sets_of(
        d,
        [
            ("dbr:Arvid_Smit", "dbp:goals", "3"),
            ("dbr:Arvid_Smit", A, "dbo:Athlete"),
        ],
    )
    # Arvid left ρ (promotion); Obama + Marcel remain parked
    assert to_set(sub.rho) == sets_of(
        d,
        [
            (
                "dbr:Barack_Obama",
                "foaf:homepage",
                '"http://www.barackobama.com/"',
            ),
            ("dbr:Marcel", A, "dbo:Athlete"),
        ],
    )
    assert sets_of(d, [("dbr:Arvid_Smit", A, "dbo:Athlete")]) <= to_set(sub.tau)
