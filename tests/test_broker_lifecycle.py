"""Subscription-lifecycle tests for the cohort-cached broker.

Golden churn: subscribe -> process -> unsubscribe -> process stays
bit-identical to fresh per-interest engine runs over each subscriber's
active window; membership changes recompile at most the touched cohort
(asserted via the per-cohort compile counters); the incremental pattern
bank keeps lane numbering stable under churn; an empty broker and 0-row
changeset sides are well-defined.
"""
import numpy as np
import pytest

from repro.core import (
    Broker,
    Dictionary,
    IncrementalPatternBank,
    InterestExpr,
    IrapEngine,
    StepCapacities,
    compile_interest,
    to_set,
)

A = "rdf:type"
CAPS = StepCapacities(n_removed=16, n_added=16, tau=64, rho=64, pulls=32)


def star2(target: str, cls: str, pred: str) -> InterestExpr:
    return InterestExpr.parse(
        "g", target, bgp=[("?a", A, cls), ("?a", pred, "?v")]
    )


def star2_ogp(target: str, cls: str, pred: str) -> InterestExpr:
    """Different static shape than :func:`star2` (carries an OGP pattern)."""
    return InterestExpr.parse(
        "g",
        target,
        bgp=[("?a", A, cls), ("?a", pred, "?v")],
        ogp=[("?a", "p:page", "?w")],
    )


@pytest.fixture()
def universe():
    d = Dictionary()
    tau0 = d.encode_triples(
        [
            ("e:1", A, "c:Athlete"),
            ("e:2", A, "c:Athlete"),
            ("e:2", "p:goals", "96"),
            ("e:3", A, "c:Team"),
        ]
    )
    changesets = [
        (
            d.encode_triples([("e:2", "p:goals", "96")]),
            d.encode_triples([("e:2", "p:goals", "216"), ("e:4", A, "c:Athlete")]),
        ),
        (
            np.zeros((0, 3), np.int32),
            d.encode_triples([("e:4", "p:goals", "3"), ("e:3", "p:rank", "1")]),
        ),
        (
            d.encode_triples([("e:4", "p:goals", "3")]),
            d.encode_triples([("e:1", "p:goals", "7")]),
        ),
    ]
    return d, tau0, changesets


def assert_state_matches(sub, ref, label):
    assert to_set(sub.tau) == to_set(ref.tau), label
    assert to_set(sub.rho) == to_set(ref.rho), label


def assert_outputs_identical(got, want, label):
    for field in ("r", "r_i", "r_prime", "a", "a_i"):
        got_f, want_f = getattr(got, field), getattr(want, field)
        assert np.array_equal(
            np.asarray(got_f.spo), np.asarray(want_f.spo)
        ), (label, field)
        assert int(got_f.n) == int(want_f.n), (label, field)


def test_golden_churn_parity(universe):
    """subscribe -> process -> unsubscribe -> process == fresh per-interest
    runs over each subscriber's active window."""
    d, tau0, changesets = universe
    ath = star2("t:a", "c:Athlete", "p:goals")
    team = star2("t:b", "c:Team", "p:rank")
    late = star2("t:c", "c:Athlete", "p:goals")

    broker = Broker(d)
    sub_ath = broker.subscribe(ath, CAPS, initial_target=tau0)
    sub_team = broker.subscribe(team, CAPS, initial_target=tau0)
    outs1 = broker.process_changeset(*changesets[0])
    broker.unsubscribe(sub_ath)
    outs2 = broker.process_changeset(*changesets[1])
    sub_late = broker.subscribe(late, CAPS, initial_target=tau0)
    outs3 = broker.process_changeset(*changesets[2])

    engine = IrapEngine(d)
    ref_ath = engine.register_interest(ath, CAPS, initial_target=tau0)
    ref_team = engine.register_interest(team, CAPS, initial_target=tau0)
    ref_late = engine.register_interest(late, CAPS, initial_target=tau0)

    want_ath = ref_ath.apply(*changesets[0])  # active: cs1 only
    want_team = [ref_team.apply(*cs) for cs in changesets]  # cs1..cs3
    want_late = ref_late.apply(*changesets[2])  # active: cs3 only

    assert_outputs_identical(outs1[0], want_ath, "athlete cs1")
    assert_outputs_identical(outs1[1], want_team[0], "team cs1")
    assert_outputs_identical(outs2[0], want_team[1], "team cs2")
    assert_outputs_identical(outs3[0], want_team[2], "team cs3")
    assert_outputs_identical(outs3[1], want_late, "late cs3")
    assert_state_matches(sub_team, ref_team, "team state")
    assert_state_matches(sub_late, ref_late, "late state")
    # the unsubscribed subscriber's state froze at its last evaluation
    assert_state_matches(sub_ath, ref_ath, "athlete frozen state")


def test_membership_change_recompiles_at_most_own_cohort(universe):
    """Each subscribe/unsubscribe triggers <= 1 cohort compile on the next
    pass; same-shape re-subscription reuses cached executables outright."""
    d, tau0, changesets = universe
    # pre-encode every interest constant so the id space (and with it the
    # cohort keys) stays fixed across the whole churn sequence
    for t in ("c:Athlete", "c:Team", "p:goals", "p:rank", "p:other", "p:page"):
        d.encode_term(t)
    broker = Broker(d)
    a0 = broker.subscribe(star2("t:0", "c:Athlete", "p:goals"), CAPS,
                          initial_target=tau0)
    broker.subscribe(star2_ogp("t:1", "c:Team", "p:rank"), CAPS,
                     initial_target=tau0)
    broker.process_changeset(*changesets[0])
    base = sum(broker.cohort_compiles.values())
    assert base == 2  # one executable per shape cohort

    # same-shape subscribe: cohort grows 1 -> 2 (padded 2) -> one compile;
    # the OGP cohort must reuse its cached executable
    broker.subscribe(star2("t:2", "c:Athlete", "p:other"), CAPS)
    broker.process_changeset(*changesets[1])
    delta1 = sum(broker.cohort_compiles.values()) - base
    assert delta1 == 1

    # unsubscribe back to the already-cached padded size: zero compiles
    broker.unsubscribe(a0)
    broker.process_changeset(*changesets[2])
    delta2 = sum(broker.cohort_compiles.values()) - base - delta1
    assert delta2 == 0

    # re-subscribe the same shape again: padded size seen before -> zero
    broker.subscribe(star2("t:3", "c:Athlete", "p:goals"), CAPS)
    broker.process_changeset(*changesets[0])
    delta3 = sum(broker.cohort_compiles.values()) - base - delta1 - delta2
    assert delta3 == 0
    # and rejit time was accounted separately from evaluation time
    assert all(st.rejit_s <= st.elapsed_s for st in broker.stats)


def test_empty_broker_and_empty_changesets(universe):
    """Unsubscribing the last subscriber clears the bank; processing an
    empty broker and 0-row changeset sides is well-defined."""
    d, tau0, changesets = universe
    broker = Broker(d)
    empty_cs = (np.zeros((0, 3), np.int32), np.zeros((0, 3), np.int32))
    assert broker.process_changeset(*empty_cs) == []

    sub = broker.subscribe(star2("t:0", "c:Athlete", "p:goals"), CAPS,
                           initial_target=tau0)
    assert broker.bank.n_lanes == 2
    broker.unsubscribe(sub)
    assert broker.bank.n_lanes == 0 and broker.bank.n_live == 0
    assert broker.process_changeset(*changesets[0]) == []

    # re-subscribing after a full drain starts from a fresh bank
    sub2 = broker.subscribe(star2("t:1", "c:Team", "p:rank"), CAPS,
                            initial_target=tau0)
    outs = broker.process_changeset(*changesets[1])
    engine = IrapEngine(d)
    ref = engine.register_interest(sub2.expr, CAPS, initial_target=tau0)
    want = ref.apply(*changesets[1])
    assert_outputs_identical(outs[0], want, "post-drain subscriber")
    # 0-row sides with live subscribers produce empty outputs
    outs = broker.process_changeset(*empty_cs)
    assert int(outs[0].r.n) == 0 and int(outs[0].a.n) == 0


def test_shared_target_single_index_build(universe):
    """share_target=True subscribers share one replica (and one
    build_index inside the cohort step) and stay bit-identical to an
    independent engine run."""
    d, tau0, changesets = universe
    expr = star2("t:shared", "c:Athlete", "p:goals")
    broker = Broker(d)
    s1 = broker.subscribe(expr, CAPS, initial_target=tau0)
    s2 = broker.subscribe(expr, CAPS, share_target=True)
    assert s2.tau is s1.tau and s2.share_tag is s1

    engine = IrapEngine(d)
    ref = engine.register_interest(expr, CAPS, initial_target=tau0)
    for cs in changesets:
        outs = broker.process_changeset(*cs)
        want = ref.apply(*cs)
        assert outs[0] is outs[1]  # one evaluation fanned out
        assert_outputs_identical(outs[0], want, "shared twin")
    assert broker.subs[0].tau is broker.subs[1].tau
    assert_state_matches(s2, ref, "shared twin state")
    # the subsumption lattice (default) collapses the identical twins into
    # ONE cohort slot: (ncp, nup) == (1, 1)
    assert any(
        k[4] == 1 and k[5] == 1
        for k in broker.cohort_compiles
        if k[0] == "cohort"
    )

    # lattice off: both members get slots but still share one unique
    # target replica — the executable specializes to (ncp, nup) == (2, 1)
    # and build_index(τ) runs once for the pair
    broker_off = Broker(d, subsume_interests=False)
    b1 = broker_off.subscribe(expr, CAPS, initial_target=tau0)
    b2 = broker_off.subscribe(expr, CAPS, share_target=True)
    assert b2.tau is b1.tau
    ref_off = IrapEngine(d).register_interest(
        expr, CAPS, initial_target=tau0
    )
    for cs in changesets:
        outs = broker_off.process_changeset(*cs)
        want = ref_off.apply(*cs)
        assert_outputs_identical(outs[0], want, "shared twin (lattice off)")
        assert_outputs_identical(outs[1], want, "shared twin (lattice off)")
    assert any(
        k[4] == 2 and k[5] == 1
        for k in broker_off.cohort_compiles
        if k[0] == "cohort"
    )


# ---------------------------------------------------------------------------
# incremental pattern bank (layer 2) unit tests
# ---------------------------------------------------------------------------

def _plan(d, cls, pred):
    return compile_interest(star2("t", cls, pred), d)


def test_incremental_bank_stable_lanes_and_tombstones():
    d = Dictionary()
    bank = IncrementalPatternBank()
    p1 = _plan(d, "c:A", "p:x")
    p2 = _plan(d, "c:A", "p:y")  # shares the type pattern with p1
    l1 = bank.add_plan(p1)
    l2 = bank.add_plan(p2)
    assert l1 == (0, 1) and l2 == (0, 2)  # dedup: shared type lane
    assert bank.n_lanes == 3 and bank.n_live == 3

    bank.remove_plan(l2)
    # shared lane survives (refcounted), p2's own lane is tombstoned
    assert bank.n_live == 2 and bank.n_lanes == 3
    assert l1 == (0, 1)  # untouched
    pad = bank.patterns_padded()
    assert pad.shape == (32, 3)
    assert np.array_equal(pad[list(l1)], p1.patterns)

    # tombstoned lane is reused by the next registration: no growth
    p3 = _plan(d, "c:A", "p:z")
    l3 = bank.add_plan(p3)
    assert set(l3) == {0, 2} and bank.n_lanes == 3


def test_incremental_bank_compaction_remap():
    """Below the 32-lane padded floor compaction cannot shrink the device
    bank shape, so it only runs when forced; the remap is still exact."""
    d = Dictionary()
    bank = IncrementalPatternBank()
    plans = [_plan(d, f"c:{i}", f"p:{i}") for i in range(4)]
    lanes = [bank.add_plan(p) for p in plans]
    for ln in lanes[:3]:
        bank.remove_plan(ln)
    assert bank.n_live == 2  # survivor's two patterns
    # 8 allocated lanes pad to the 32-lane floor either way: no shape win,
    # so the padded-boundary policy declines to churn the lane maps
    assert bank.maybe_compact() is None
    remap = bank.maybe_compact(force=True)
    assert remap is not None
    new_lanes = tuple(remap[l] for l in lanes[3])
    assert set(new_lanes) == {0, 1}
    assert np.array_equal(
        bank.patterns_padded()[list(new_lanes)], plans[3].patterns
    )
    assert bank.maybe_compact(force=True) is None  # idempotent


def test_compaction_fires_only_on_padded_boundary_shrink():
    """Compaction triggers exactly when live lanes pad to a strictly
    smaller power-of-two than the current allocation — i.e. when it can
    shrink executables' padded bank-word input shapes."""
    d = Dictionary()
    bank = IncrementalPatternBank()
    plans = [_plan(d, f"c:{i}", f"p:{i}") for i in range(17)]
    lanes = [bank.add_plan(p) for p in plans]
    assert bank.n_lanes == 34 and bank.n_lanes_padded == 64
    # removing one plan leaves 32 live lanes in a 64-padded bank: the
    # padded shape can halve, so compaction fires and shrinks it
    bank.remove_plan(lanes[0])
    assert bank.n_live == 32
    remap = bank.maybe_compact()
    assert remap is not None
    assert bank.n_lanes == 32 and bank.n_lanes_padded == 32
    for ln, plan in zip(lanes[1:], plans[1:]):
        new = [remap[l] for l in ln]
        assert np.array_equal(bank.patterns_padded()[new], plan.patterns)
    # further removals cannot shrink below the 32-lane floor: no compaction
    bank.remove_plan(tuple(remap[l] for l in lanes[1]))
    assert bank.maybe_compact() is None


def test_incremental_bank_matches_batch_build():
    """Pure-append incremental construction equals build_pattern_bank."""
    from repro.core import build_pattern_bank

    d = Dictionary()
    plans = [_plan(d, f"c:{i % 2}", f"p:{i}") for i in range(5)]
    bank = IncrementalPatternBank()
    lanes = [bank.add_plan(p) for p in plans]
    ref = build_pattern_bank(plans)
    assert tuple(lanes) == ref.lanes
    assert np.array_equal(
        bank.patterns_padded()[: ref.n_lanes], ref.patterns
    )
