"""Device-sharded cohort routing == the single-device broker, bit for bit.

Three brokers consume identical streams (same dictionary insertion order,
same churn schedule):

  * single  — no mesh (the PR 3 broker),
  * placed  — cohorts placed on mesh devices per ``CohortPlacement``; the
              frontier pass dispatches cohort calls grouped by device,
  * sharded — every cohort pass runs inside shard_map over the mesh
              (hash-partitioned τ shards, all_to_all-routed probes,
              block-gather-stitched bank words).

All per-subscriber outputs and all replica state (τ, ρ) must be
bit-identical across the three, and the eager subscribers additionally
match the seed per-interest engine (``InterestSubscription.apply``) on
every changeset.  The golden test runs in a subprocess with 8 forced host
devices; the hypothesis property randomizes the placement policy and the
churn order and runs in-process on a >= 4-device host mesh (CI provides it
via ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_cohort_placement_policies():
    """Host-side placement logic: sticky, balanced, pinned."""
    from repro.core import CohortPlacement

    rr = CohortPlacement()
    assert [rr.assign(f"c{i}", 4, 3) for i in range(5)] == [0, 1, 2, 0, 1]
    assert rr.assign("c0", 4, 3) == 0  # sticky across calls

    lb = CohortPlacement(mode="load_balanced")
    assert lb.assign("big", 16, 2) == 0
    assert lb.assign("s1", 2, 2) == 1  # least-loaded device
    assert lb.assign("s2", 2, 2) == 1  # 2 < 16: still device 1
    assert lb.assign("s3", 16, 2) == 1  # 4 < 16
    assert lb.assign("s4", 2, 2) == 0  # now 16 < 20
    assert lb.assign("s1", 8, 2) == 1  # sticky even after growth

    pin = CohortPlacement(mode="pinned", pins={"a": 7}, default=1)
    assert pin.assign("a", 4, 4) == 3  # 7 % 4
    assert pin.assign("b", 4, 4) == 1  # default fallback

    with pytest.raises(ValueError):
        CohortPlacement(mode="nope")


GOLDEN_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np

    from repro.core import (
        Broker, CohortPlacement, Dictionary, InterestExpr, IrapEngine,
        PushPolicy, StepCapacities,
    )

    A = "rdf:type"
    CAPS = StepCapacities(n_removed=16, n_added=16, tau=64, rho=64, pulls=32)
    from repro.core.distributed import make_mesh_compat
    mesh = make_mesh_compat((8,), ("shard",))

    EXPRS = [
        InterestExpr.parse("g", "t0",
            bgp=[("?a", A, "c:Athlete"), ("?a", "p:goals", "?v")]),
        InterestExpr.parse("g", "t1",
            bgp=[("?a", A, "c:Team"), ("?a", "p:rank", "?v")]),
        InterestExpr.parse("g", "t2", bgp=[("?a", "p:goals", "?v")]),
        InterestExpr.parse("g", "t3",
            bgp=[("?a", A, "c:Athlete"), ("?a", "p:plays", "?t"),
                 ("?t", "p:rank", "?r")],
            ogp=[("?a", "p:page", "?w")]),
    ]

    def stream(d, n, seed=3):
        rng = np.random.default_rng(seed)
        def rows(k):
            out = set()
            for _ in range(k):
                e = f"e:{rng.integers(0, 12)}"
                kind = rng.integers(0, 6)
                if kind == 0:
                    out.add((e, A, f"c:{['Athlete','Team'][rng.integers(2)]}"))
                elif kind == 1:
                    out.add((e, "p:goals", str(int(rng.integers(0, 30)))))
                elif kind == 2:
                    out.add((e, "p:rank", str(int(rng.integers(0, 5)))))
                elif kind == 3:
                    out.add((e, "p:plays", f"e:{rng.integers(0, 12)}"))
                elif kind == 4:
                    out.add((e, "p:page", f"w{rng.integers(0, 4)}"))
                else:
                    out.add((e, "p:noise", f"o{rng.integers(0, 6)}"))
            return d.encode_triples(sorted(out))
        return [(rows(int(rng.integers(0, 5))), rows(int(rng.integers(1, 8))))
                for _ in range(n)]

    def tau0_of(d):
        return d.encode_triples([
            ("e:1", A, "c:Athlete"), ("e:1", "p:goals", "10"),
            ("e:2", A, "c:Team"), ("e:2", "p:rank", "1"),
            ("e:3", "p:plays", "e:2"),
        ])

    def drive(make_broker):
        # identical dictionary insertion order per run -> identical ids
        d = Dictionary()
        tau0 = tau0_of(d)
        st = stream(d, 8)
        broker = make_broker(d)
        subs = {}
        subs["A"] = broker.subscribe(EXPRS[0], CAPS, initial_target=tau0)
        subs["B"] = broker.subscribe(
            EXPRS[1], CAPS, initial_target=tau0, policy=PushPolicy.every(2))
        subs["C"] = broker.subscribe(
            EXPRS[0], CAPS, initial_target=tau0, share_target=True)
        outs = []
        for i, cs in enumerate(st):
            if i == 3:  # churn mid-stream: one new cohort, one departure
                subs["D"] = broker.subscribe(
                    EXPRS[3], CAPS, initial_target=tau0)
                broker.unsubscribe(subs.pop("B"))
            outs.append([
                None if o is None else o for o in broker.process_changeset(*cs)
            ])
        outs.append(broker.flush())
        state = {
            name: (np.asarray(s.tau.spo), np.asarray(s.rho.spo))
            for name, s in subs.items()
        }
        return outs, state, broker, d, st, tau0

    def flat(outs):
        res = []
        for per_cs in outs:
            for o in per_cs:
                if o is None:
                    res.append(None)
                else:
                    res.append(tuple(
                        np.asarray(getattr(o, f).spo)
                        for f in ("r", "r_i", "r_prime", "a", "a_i")))
        return res

    runs = {
        "single": drive(lambda d: Broker(d)),
        "placed": drive(lambda d: Broker(
            d, mesh=mesh, placement=CohortPlacement(mode="load_balanced"))),
        "sharded": drive(lambda d: Broker(d, mesh=mesh, shard_cohorts=True)),
    }

    base_outs = flat(runs["single"][0])
    base_state = runs["single"][1]
    for name in ("placed", "sharded"):
        got = flat(runs[name][0])
        assert len(got) == len(base_outs), name
        for i, (a, b) in enumerate(zip(base_outs, got)):
            assert (a is None) == (b is None), (name, i)
            if a is None:
                continue
            for fa, fb in zip(a, b):
                assert np.array_equal(fa, fb), (name, i)
        for sub_name, (tau, rho) in runs[name][1].items():
            assert np.array_equal(tau, base_state[sub_name][0]), (name, sub_name)
            assert np.array_equal(rho, base_state[sub_name][1]), (name, sub_name)

    # seed per-interest oracle over the eager subscriber A on every changeset
    d = Dictionary()
    tau0 = tau0_of(d)
    st = stream(d, 8)
    engine = IrapEngine(d)
    ref = engine.register_interest(EXPRS[0], CAPS, initial_target=tau0)
    a_outs = [per_cs[0] for per_cs in runs["sharded"][0][:-1]]
    for i, cs in enumerate(st):
        want = ref.apply(*cs)
        got = a_outs[i]
        for f in ("r", "r_i", "r_prime", "a", "a_i"):
            assert np.array_equal(
                np.asarray(getattr(got, f).spo),
                np.asarray(getattr(want, f).spo)), ("oracle", i, f)

    # placement actually spread the cohorts; sharding spanned the mesh
    placed_devs = {k for k, v in runs["placed"][2].device_passes.items() if v}
    assert len(placed_devs) > 1, runs["placed"][2].device_passes
    assert len(runs["sharded"][2].device_passes) == 8
    print("SHARDED_GOLDEN_OK")
    """
)


@pytest.mark.slow
def test_sharded_equals_single_device_golden():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", GOLDEN_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=1800,
    )
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "SHARDED_GOLDEN_OK" in proc.stdout


def _mesh_or_skip(n: int):
    import jax

    if len(jax.devices()) < n:
        pytest.skip(
            f"needs a >= {n}-device host mesh "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=4)"
        )
    from repro.core.distributed import make_mesh_compat

    return make_mesh_compat((n,), ("shard",))


@pytest.mark.slow
def test_placement_and_churn_property():
    """Random placement policy + churn order == single-device, bit for bit."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st_mod

    mesh = _mesh_or_skip(4)

    from repro.core import (
        Broker,
        CohortPlacement,
        Dictionary,
        InterestExpr,
        PushPolicy,
        StepCapacities,
    )

    A = "rdf:type"
    caps = StepCapacities(n_removed=16, n_added=16, tau=64, rho=64, pulls=32)
    exprs = [
        InterestExpr.parse(
            "g", "t0", bgp=[("?a", A, "c:Athlete"), ("?a", "p:goals", "?v")]
        ),
        InterestExpr.parse(
            "g", "t1", bgp=[("?a", A, "c:Team"), ("?a", "p:rank", "?v")]
        ),
        InterestExpr.parse("g", "t2", bgp=[("?a", "p:goals", "?v")]),
    ]

    def rows_of(rng, d, k):
        out = set()
        for _ in range(k):
            e = f"e:{rng.integers(0, 9)}"
            kind = rng.integers(0, 4)
            if kind == 0:
                out.add((e, A, f"c:{['Athlete', 'Team'][rng.integers(2)]}"))
            elif kind == 1:
                out.add((e, "p:goals", str(int(rng.integers(0, 20)))))
            elif kind == 2:
                out.add((e, "p:rank", str(int(rng.integers(0, 4)))))
            else:
                out.add((e, "p:noise", f"o{rng.integers(0, 4)}"))
        return d.encode_triples(sorted(out))

    def drive(mode, churn_order, seed, shard: bool, use_mesh: bool):
        d = Dictionary()
        tau0 = d.encode_triples(
            [("e:1", A, "c:Athlete"), ("e:1", "p:goals", "3")]
        )
        rng = np.random.default_rng(seed)
        if use_mesh:
            broker = Broker(
                d,
                mesh=mesh,
                shard_cohorts=shard,
                placement=CohortPlacement(mode=mode),
            )
        else:
            broker = Broker(d)
        live = []
        collected = []
        for step_no, action in enumerate(churn_order):
            if action == 0 or not live:  # subscribe
                expr = exprs[step_no % len(exprs)]
                live.append(
                    broker.subscribe(
                        expr,
                        caps,
                        initial_target=tau0,
                        policy=PushPolicy.every(1 + step_no % 2),
                    )
                )
            else:  # unsubscribe the oldest
                broker.unsubscribe(live.pop(0))
            outs = broker.process_changeset(
                rows_of(rng, d, int(rng.integers(0, 4))),
                rows_of(rng, d, int(rng.integers(1, 6))),
            )
            collected.append(outs)
        collected.append(broker.flush())
        state = [
            (np.asarray(s.tau.spo), np.asarray(s.rho.spo)) for s in live
        ]
        return collected, state

    @settings(max_examples=4, deadline=None)
    @given(
        mode=st_mod.sampled_from(["round_robin", "load_balanced", "pinned"]),
        churn_order=st_mod.lists(
            st_mod.integers(min_value=0, max_value=1), min_size=3, max_size=6
        ),
        seed=st_mod.integers(min_value=0, max_value=2**16),
        shard=st_mod.booleans(),
    )
    def check(mode, churn_order, seed, shard):
        base_outs, base_state = drive(mode, churn_order, seed, shard, False)
        mesh_outs, mesh_state = drive(mode, churn_order, seed, shard, True)
        assert len(base_outs) == len(mesh_outs)
        for per_a, per_b in zip(base_outs, mesh_outs):
            assert len(per_a) == len(per_b)
            for a, b in zip(per_a, per_b):
                assert (a is None) == (b is None)
                if a is None:
                    continue
                for f in ("r", "r_i", "r_prime", "a", "a_i"):
                    assert np.array_equal(
                        np.asarray(getattr(a, f).spo),
                        np.asarray(getattr(b, f).spo),
                    )
        for (t_a, r_a), (t_b, r_b) in zip(base_state, mesh_state):
            assert np.array_equal(t_a, t_b)
            assert np.array_equal(r_a, r_b)

    check()


def test_or_reduce_words_reassembly():
    """The uint32 branch of make_or_reduce: shards holding masked (and here
    deliberately OVERLAPPING) subsets of a lane-bit words tensor reassemble
    the full tensor exactly — the OR fold is idempotent where subsets
    overlap, which the broker's disjoint block-stitching cannot cover."""
    mesh = _mesh_or_skip(4)
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import make_or_reduce, shard_map_compat
    from repro.core.triples import PAD
    from repro.kernels import ops as kops

    n = 4
    rng = np.random.default_rng(0)
    spo = jnp.asarray(rng.integers(0, 40, (32, 3)).astype(np.int32))
    bank = jnp.asarray(
        np.array(
            [[-1, 7, -1], [5, -1, -1], [-1, -1, 3], [2, 9, -1]], np.int32
        )
    )
    or_reduce = make_or_reduce("shard")

    def body(spo_in, bank_in):
        my = jax.lax.axis_index("shard")
        idx = jnp.arange(spo_in.shape[0])
        # each row is owned by TWO shards: overlap that OR absorbs exactly
        mine = (idx % n == my) | (idx % n == (my + 1) % n)
        masked = jnp.where(mine[:, None], spo_in, PAD)
        words = or_reduce(
            kops.pattern_bitmask_words(masked, bank_in).astype(jnp.uint32)
        )
        covered = or_reduce(mine)  # bool branch: union of coverage
        return words[None], covered[None]

    fn = jax.jit(
        shard_map_compat(
            body, mesh, in_specs=(P(), P()), out_specs=(P("shard"), P("shard"))
        )
    )
    words_sh, covered_sh = fn(spo, bank)
    want = np.asarray(kops.pattern_bitmask_words(spo, bank))
    for i in range(n):  # every shard reconstructed the full words tensor
        assert np.array_equal(np.asarray(words_sh[i]), want), i
    assert np.asarray(covered_sh).all()
