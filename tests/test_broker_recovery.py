"""Durable broker: WAL journal, crash recovery, delivery robustness.

The recovery contract under test: a broker rebuilt by
``Broker.recover(journal, store)`` is **bit-identical** to the crashed
broker at every journal-record boundary — same τ/ρ rows, same consumption
frontiers, same pending composed batches, same sequence clock
(:func:`repro.testing.faults.broker_state` pins the comparison). Delivery
faults (flaky/poisonous transports) must *degrade* — retry, back off,
quarantine with the frontier pinned and the batch composing — and never
halt ingest or corrupt a healthy subscriber's state.

One subtlety the delivery goldens encode: interest-filtered propagation is
*cadence-dependent* (additions are join-filtered against the evolving τ at
delivery time), so a quarantined subscriber that catches up on a composed
window is NOT compared against an eagerly-fed twin — the correct oracle is
a fault-free twin on the *same effective schedule* (policy-deferred, one
flush at the catch-up point). Redelivery of the same window is what Def-6
composition makes idempotent, and that is what recovery relies on.
"""
import shutil

import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.core import (
    Broker,
    ChangesetJournal,
    DeliveryChannel,
    PushPolicy,
    StepCapacities,
    to_numpy,
)
from repro.testing import (
    CapturingJournal,
    FakeClock,
    ScriptedTransport,
    assert_state_equal,
    broker_state,
    corrupt_tail,
    crash_at_record,
    tear_tail,
    tiny_caps,
)
from test_broker_deferred import (
    CAPS,
    _exprs,
    _stream,
    _universe,
    assert_results_identical,
)

# generous capacities for the boundary goldens: a capacity overflow inside
# a fire grows caps *before* the fire record is appended, so the captured
# boundary state would include growth the crash-side recovery (which never
# sees that record) cannot reproduce — the goldens must stay overflow-free
RCAPS = StepCapacities(n_removed=32, n_added=32, tau=128, rho=128, pulls=64)


# ---------------------------------------------------------------------------
# journal unit tests (no broker)
# ---------------------------------------------------------------------------


def _fill(journal, n, seed=0):
    rng = np.random.default_rng(seed)
    for i in range(1, n + 1):
        journal.append(
            "ingest",
            meta={"i": i},
            arrays={
                "removed": rng.integers(0, 99, (i % 3, 3)).astype(np.int32),
                "added": rng.integers(0, 99, (1 + i % 4, 3)).astype(np.int32),
            },
        )


def _roundtrip_equal(journal, n, seed=0):
    rng = np.random.default_rng(seed)
    recs = list(journal.records())
    assert [r.seq for r in recs] == list(range(1, n + 1))
    for i, r in enumerate(recs, start=1):
        assert r.kind == "ingest" and r.meta == {"i": i}
        np.testing.assert_array_equal(
            r.arrays["removed"],
            rng.integers(0, 99, (i % 3, 3)).astype(np.int32),
        )
        np.testing.assert_array_equal(
            r.arrays["added"],
            rng.integers(0, 99, (1 + i % 4, 3)).astype(np.int32),
        )


def test_journal_append_reopen_roundtrip(tmp_path):
    j = ChangesetJournal(tmp_path / "wal", fsync=False)
    _fill(j, 7)
    assert j.last_seq == 7
    j.close()
    j2 = ChangesetJournal(tmp_path / "wal", fsync=False)
    assert j2.last_seq == 7 and not j2.torn
    _roundtrip_equal(j2, 7)
    # appends continue the sequence across reopen
    assert j2.append("ingest", meta={"i": 8}) == 8
    assert [r.seq for r in j2.records(start_seq=7)] == [7, 8]


def test_journal_rotation_and_compaction(tmp_path):
    j = ChangesetJournal(tmp_path / "wal", fsync=False, segment_bytes=256)
    _fill(j, 20)
    assert len(j.segments) > 3, "tiny segment_bytes must rotate"
    _roundtrip_equal(j, 20)
    # compaction keeps every record >= keep_from_seq readable (it drops
    # whole leading segments only, so earlier records may survive)
    keep = 12
    removed = j.compact(keep_from_seq=keep)
    assert removed > 0
    recs = list(j.records())
    assert recs[0].seq <= keep and recs[-1].seq == 20
    assert {r.seq for r in recs} >= set(range(keep, 21))
    # append after compaction still continues the sequence
    assert j.append("ingest", meta={"i": 21}) == 21


@pytest.mark.parametrize("cut", [1, 5, 17])
def test_journal_torn_tail_truncates(tmp_path, cut):
    j = ChangesetJournal(tmp_path / "wal", fsync=False)
    _fill(j, 5)
    j.close()
    assert tear_tail(tmp_path / "wal", cut) == cut
    j2 = ChangesetJournal(tmp_path / "wal", fsync=False)
    assert j2.torn and j2.last_seq == 4 and j2.dropped_bytes > 0
    assert [r.seq for r in j2.records()] == [1, 2, 3, 4]
    # the torn slot is reused: the journal stays densely sequenced
    assert j2.append("ingest", meta={"i": 5}) == 5


def test_journal_crc_rejects_corruption(tmp_path):
    j = ChangesetJournal(tmp_path / "wal", fsync=False)
    _fill(j, 5)
    j.close()
    assert corrupt_tail(tmp_path / "wal", seed=7) > 0
    j2 = ChangesetJournal(tmp_path / "wal", fsync=False)
    assert j2.torn and j2.last_seq == 4
    assert [r.seq for r in j2.records()] == [1, 2, 3, 4]


# ---------------------------------------------------------------------------
# crash recovery goldens
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def journaled_run(tmp_path_factory):
    """One journaled broker run with a mid-stream snapshot: mixed cadences,
    a pre-append state capture per record, and the final state."""
    tmp = tmp_path_factory.mktemp("durable")
    d, tau0 = _universe()
    captures = {}
    j = CapturingJournal(
        tmp / "wal",
        fsync=False,
        on_append=lambda seq, kind: captures.__setitem__(
            seq, broker_state(b)
        ),
    )
    b = Broker(d, journal=j)
    exprs = _exprs()
    policies = [PushPolicy(), PushPolicy.every(2), PushPolicy.every(3)]
    for i in range(3):
        b.subscribe(exprs[i], RCAPS, initial_target=tau0, policy=policies[i])
    stream = _stream(d, 4, seed=3)
    store = CheckpointStore(tmp / "ckpt")
    for i, (rm, ad) in enumerate(stream):
        b.process_changeset(rm, ad)
        if i == 1:
            b.snapshot(store)  # mid-stream: pending batches straddle it
    b.flush()
    final = broker_state(b)
    j.sync()
    j.close()
    return {
        "tmp": tmp,
        "dictionary": d,
        "jdir": tmp / "wal",
        "store": store,
        "captures": captures,
        "final": final,
        "n": max(captures),
    }


def test_crash_at_every_boundary_recovers_bit_identical(journaled_run):
    """Kill the broker between any two journal appends: recovery from the
    surviving prefix reproduces the captured pre-append state exactly —
    τ/ρ rows, frontiers, pending composed batches, sequence clock."""
    run = journaled_run
    n, captures = run["n"], run["captures"]
    assert n >= 8  # subscribes + ingests + fire commits all journal
    for k in range(n + 1):
        cdst = run["tmp"] / f"crash{k}"
        kept = crash_at_record(run["jdir"], cdst, k)
        assert kept == k, (kept, k)
        j2 = ChangesetJournal(cdst, fsync=False)
        assert j2.last_seq == k
        r = Broker.recover(j2, run["store"], dictionary=run["dictionary"])
        # the capture taken before record k+1 is the state of a broker
        # holding exactly k durable records — except its sequence clock,
        # which had already consumed record k+1's tick
        want = (
            run["final"] if k == n else {**captures[k + 1], "seq": k}
        )
        assert_state_equal(want, broker_state(r))


@pytest.mark.parametrize("cut", [1, 5, 17])
def test_torn_tail_recovers_to_previous_boundary(journaled_run, cut):
    run = journaled_run
    n = run["n"]
    cdst = run["tmp"] / f"torn{cut}"
    shutil.copytree(run["jdir"], cdst)
    tear_tail(cdst, cut)
    j = ChangesetJournal(cdst, fsync=False)
    assert j.torn and j.last_seq == n - 1 and j.dropped_bytes > 0
    r = Broker.recover(j, run["store"], dictionary=run["dictionary"])
    assert_state_equal(
        {**run["captures"][n], "seq": n - 1}, broker_state(r)
    )


def test_corrupt_tail_recovers_to_previous_boundary(journaled_run):
    run = journaled_run
    n = run["n"]
    cdst = run["tmp"] / "corrupt"
    shutil.copytree(run["jdir"], cdst)
    assert corrupt_tail(cdst, seed=7) > 0
    j = ChangesetJournal(cdst, fsync=False)
    assert j.torn and j.last_seq == n - 1
    r = Broker.recover(j, run["store"], dictionary=run["dictionary"])
    assert_state_equal(
        {**run["captures"][n], "seq": n - 1}, broker_state(r)
    )


def test_recovery_from_journal_alone(tmp_path):
    """No snapshot at all: full-journal replay rebuilds the broker."""
    d, tau0 = _universe()
    j = ChangesetJournal(tmp_path / "wal", fsync=False)
    b = Broker(d, journal=j)
    exprs = _exprs()
    b.subscribe(exprs[0], RCAPS, initial_target=tau0)
    b.subscribe(exprs[2], RCAPS, initial_target=tau0,
                policy=PushPolicy.every(2))
    for rm, ad in _stream(d, 3, seed=9):
        b.process_changeset(rm, ad)
    b.flush()
    j.sync()
    j2 = ChangesetJournal(tmp_path / "wal", fsync=False)
    r = Broker.recover(j2, dictionary=d)
    assert_state_equal(broker_state(b), broker_state(r))


def test_snapshot_compaction_preserves_recovery(tmp_path):
    """Snapshot, drop the journal segments replay can no longer need, keep
    streaming: recovery over the compacted journal stays bit-identical."""
    d, tau0 = _universe()
    j = ChangesetJournal(tmp_path / "wal", fsync=False, segment_bytes=256)
    b = Broker(d, journal=j)
    exprs = _exprs()
    for i in range(3):
        b.subscribe(exprs[i], CAPS, initial_target=tau0,
                    policy=PushPolicy.every(2))
    store = CheckpointStore(tmp_path / "ckpt")
    removed = 0
    for i, (rm, ad) in enumerate(_stream(d, 10, seed=5)):
        b.process_changeset(rm, ad)
        if i == 6:
            b.snapshot(store)
            removed = b.compact_journal()
    b.flush()
    j.sync()
    assert removed > 0, "segment rotation + snapshot must free segments"
    j2 = ChangesetJournal(tmp_path / "wal", fsync=False)
    r = Broker.recover(j2, store, dictionary=d)
    assert_state_equal(broker_state(b), broker_state(r))


def test_recovery_refuses_overcompacted_journal(tmp_path):
    """A journal whose surviving records start past what replay needs (a
    compacted-away or lost segment) must fail loudly, not rebuild silently
    wrong state."""
    d, tau0 = _universe()
    j = ChangesetJournal(tmp_path / "wal", fsync=False, segment_bytes=128)
    b = Broker(d, journal=j)
    b.subscribe(_exprs()[0], CAPS, initial_target=tau0)
    for rm, ad in _stream(d, 6, seed=4):
        b.process_changeset(rm, ad)
    j.sync()
    # no snapshot exists, so replay needs seq 1 — force-drop the head
    assert j.compact(keep_from_seq=j.last_seq) > 0
    j2 = ChangesetJournal(tmp_path / "wal", fsync=False)
    with pytest.raises(RuntimeError, match="compacted away or lost"):
        Broker.recover(j2, dictionary=d)


def test_crash_boundary_property_random_schedules():
    """Hypothesis sweep: random cadences, random streams, crash at a random
    boundary — recovery always lands on the captured state."""
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
    )
    import tempfile
    from pathlib import Path

    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @given(
        seed=st.integers(0, 2**16),
        ks=st.lists(st.integers(1, 3), min_size=1, max_size=2),
        n_steps=st.integers(2, 3),
        crash_frac=st.floats(0.0, 1.0),
    )
    @settings(
        max_examples=5,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def prop(seed, ks, n_steps, crash_frac):
        tmp = Path(tempfile.mkdtemp())
        try:
            d, tau0 = _universe()
            captures = {}
            j = CapturingJournal(
                tmp / "wal",
                fsync=False,
                on_append=lambda seq, kind: captures.__setitem__(
                    seq, broker_state(b)
                ),
            )
            b = Broker(d, journal=j)
            exprs = _exprs()
            for i, kk in enumerate(ks):
                b.subscribe(
                    exprs[i % len(exprs)], RCAPS, initial_target=tau0,
                    policy=PushPolicy.every(kk),
                )
            for rm, ad in _stream(d, n_steps, seed=seed):
                b.process_changeset(rm, ad)
            b.flush()
            final = broker_state(b)
            j.sync()
            j.close()
            n = max(captures)
            k = min(n, int(round(crash_frac * n)))
            kept = crash_at_record(tmp / "wal", tmp / "crash", k)
            assert kept == k
            j2 = ChangesetJournal(tmp / "crash", fsync=False)
            r = Broker.recover(j2, dictionary=d)
            want = final if k == n else {**captures[k + 1], "seq": k}
            assert_state_equal(want, broker_state(r))
        finally:
            shutil.rmtree(tmp, ignore_errors=True)

    prop()


# ---------------------------------------------------------------------------
# delivery robustness: retry / backoff / quarantine / backpressure
# ---------------------------------------------------------------------------


def test_quarantine_pins_frontier_and_composed_catchup():
    """A poisonous subscriber quarantines after ``quarantine_after``
    consecutive failed deliveries; its frontier pins while its batch keeps
    composing, the healthy subscriber is unaffected, and readmission
    delivers the composed window exactly once. The catch-up oracle is a
    fault-free twin on the same effective schedule (policy-deferred, one
    flush) — NOT an eager twin: interest filtering is cadence-dependent."""
    clk = FakeClock()
    tr = ScriptedTransport(scripts={0: ["fail"] * 10}, clock=clk)
    ch = DeliveryChannel(
        tr, max_attempts=1, base_backoff_s=1.0, backoff_factor=2.0,
        jitter=0.0, quarantine_after=3, clock=clk, sleep=clk.sleep,
    )
    d, tau0 = _universe()
    exprs = _exprs()
    b = Broker(d, channel=ch)
    s0 = b.subscribe(exprs[0], CAPS, initial_target=tau0)  # poisoned
    s1 = b.subscribe(exprs[2], CAPS, initial_target=tau0)  # healthy

    d2, tau0b = _universe()
    twin = Broker(d2)
    t0 = twin.subscribe(
        exprs[0], CAPS, initial_target=tau0b, policy=PushPolicy(every_k=None)
    )
    t1 = twin.subscribe(exprs[2], CAPS, initial_target=tau0b)

    stream = _stream(d, 6, seed=11)
    stream_t = _stream(d2, 6, seed=11)
    for i, ((rm, ad), (rm2, ad2)) in enumerate(zip(stream, stream_t)):
        outs = b.process_changeset(rm, ad)
        outs_t = twin.process_changeset(rm2, ad2)
        # the healthy subscriber never notices the poisoned one
        assert_results_identical([outs[1]], [outs_t[1]], ("healthy", i))
        clk.advance(10.0)  # let each backoff elapse between changesets

    assert ch.is_quarantined(s0) and ch.stats.quarantines == 1
    assert not ch.eligible(s0) and ch.eligible(s1)
    assert s0.since < s1.since  # pinned frontier, healthy one advanced
    batch = b._batches[s0.since]
    assert batch.n_changesets > 1  # the pinned window kept composing

    # readmit: the whole composed window delivers in ONE transport call
    ch.readmit(s0)
    tr.scripts[0] = []
    b.flush([s0])
    assert s0.since > b._last_cid
    assert len(tr.delivered.get(0, [])) == 1

    twin.flush([t0])
    np.testing.assert_array_equal(to_numpy(s0.tau), to_numpy(t0.tau))
    np.testing.assert_array_equal(to_numpy(s0.rho), to_numpy(t0.rho))
    np.testing.assert_array_equal(to_numpy(s1.tau), to_numpy(t1.tau))
    np.testing.assert_array_equal(to_numpy(s1.rho), to_numpy(t1.rho))


def test_backoff_schedule_golden():
    """Exact exponential backoff against a fake clock (jitter=0): a failed
    delivery at t=0 retries at 1.0, a second failure at t=1 retries at
    3.0, the third attempt delivers and clears the failure state."""
    clk = FakeClock()
    tr = ScriptedTransport(scripts={0: ["fail"] * 2}, clock=clk)
    ch = DeliveryChannel(
        tr, max_attempts=1, base_backoff_s=1.0, backoff_factor=2.0,
        jitter=0.0, quarantine_after=5, clock=clk, sleep=clk.sleep,
    )
    d, tau0 = _universe()
    b = Broker(d, channel=ch)
    u0 = b.subscribe(_exprs()[0], CAPS, initial_target=tau0)
    rm, ad = _stream(d, 1, seed=2)[0]
    b.process_changeset(rm, ad)  # attempt 1 fails at t=0
    assert ch.failures(u0) == 1 and ch.next_retry_at(u0) == 1.0
    assert not ch.retry_due(u0)  # backoff not yet elapsed
    clk.advance(1.0)
    assert ch.retry_due(u0)
    b.flush([u0])  # attempt 2 fails at t=1
    assert ch.failures(u0) == 2 and ch.next_retry_at(u0) == 3.0
    clk.advance(2.0)
    b.flush([u0])  # attempt 3 succeeds
    assert ch.failures(u0) == 0 and u0.since > b._last_cid
    assert tr.log == [(0, "fail"), (0, "fail"), (0, "ok")]


def test_backpressure_pump_terminates_into_quarantine():
    """With a full in-flight retry queue the ingest path blocks on the
    injected clock and pumps retries; every pump either acks or moves a
    subscriber toward quarantine, so ingest always makes progress — a
    poisonous consumer degrades to quarantine, never a deadlock."""
    clk = FakeClock()
    tr = ScriptedTransport(scripts={0: ["fail"] * 10}, clock=clk)
    ch = DeliveryChannel(
        tr, max_attempts=1, base_backoff_s=1.0, jitter=0.0,
        quarantine_after=2, max_in_flight=1, clock=clk, sleep=clk.sleep,
    )
    d, tau0 = _universe()
    exprs = _exprs()
    b = Broker(d, channel=ch)
    s0 = b.subscribe(exprs[0], CAPS, initial_target=tau0)
    s1 = b.subscribe(exprs[2], CAPS, initial_target=tau0)
    for rm, ad in _stream(d, 4, seed=13):
        b.process_changeset(rm, ad)  # never deadlocks on the fake clock
    assert b._last_cid > 0 and ch.is_quarantined(s0)
    assert ch.in_flight() == 0  # quarantine emptied the retry queue
    assert s1.since > s0.since  # healthy subscriber kept advancing
    assert len(tr.delivered.get(1, [])) >= 1


def test_timeout_counts_as_failed_delivery():
    """A transport that 'succeeds' slower than ``timeout_s`` on the
    injected clock is a failed delivery: the subscriber stays pinned."""
    clk = FakeClock()
    tr = ScriptedTransport(
        scripts={0: ["timeout"]}, clock=clk, timeout_advance=5.0
    )
    ch = DeliveryChannel(
        tr, max_attempts=1, timeout_s=1.0, jitter=0.0,
        base_backoff_s=1.0, clock=clk, sleep=clk.sleep,
    )
    d, tau0 = _universe()
    b = Broker(d, channel=ch)
    u0 = b.subscribe(_exprs()[0], CAPS, initial_target=tau0)
    rm, ad = _stream(d, 1, seed=2)[0]
    b.process_changeset(rm, ad)
    assert ch.stats.timeouts == 1 and ch.failures(u0) == 1
    assert u0.since <= b._last_cid  # not committed


def _goal_stream(d, n, per=4):
    """τ-growing stream: every changeset adds ``per`` fresh matching rows,
    each small enough to dodge the host-side input-capacity pre-growth —
    so with tiny τ capacity the *output* side must overflow mid-run."""
    z = np.zeros((0, 3), np.int32)
    return [
        (
            z,
            d.encode_triples(
                [(f"e:{i}-{j}", "p:goals", str(i * per + j))
                 for j in range(per)]
            ),
        )
        for i in range(n)
    ]


def test_degraded_fire_ceiling_falls_back_bit_identical():
    """With ``max_fire_retries=0`` an overflowing fire falls back to the
    per-subscriber seed path instead of recompile-retrying the cohort —
    same outputs, same τ, with the degradation surfaced in
    ``Broker.degraded_fires``."""
    d, tau0 = _universe()
    exprs = _exprs()
    b_deg = Broker(d, max_fire_retries=0)
    g0 = b_deg.subscribe(exprs[2], tiny_caps(), initial_target=tau0)
    d2, tau0b = _universe()
    b_ret = Broker(d2)  # default ceiling: whole-fire recompile-retry path
    g1 = b_ret.subscribe(exprs[2], tiny_caps(), initial_target=tau0b)
    for (rm, ad), (rm2, ad2) in zip(
        _goal_stream(d, 6), _goal_stream(d2, 6)
    ):
        o1 = b_deg.process_changeset(rm, ad)
        o2 = b_ret.process_changeset(rm2, ad2)
        assert_results_identical(o1, o2, "degraded vs retry")
    np.testing.assert_array_equal(to_numpy(g0.tau), to_numpy(g1.tau))
    assert b_deg.degraded_fires > 0 and b_ret.degraded_fires == 0
    assert any(st.degraded_fires > 0 for st in b_deg.stats)  # surfaced


# ---------------------------------------------------------------------------
# unified sequence clock
# ---------------------------------------------------------------------------


def test_unified_clock_journal_on_off_identical():
    """subscribe/ingest/committed-fire each consume one sequence tick with
    or without a journal, so journal-on and journal-off brokers assign
    identical changeset ids, frontiers, and stats sequence points."""
    d, tau0 = _universe()
    exprs = _exprs()
    import tempfile
    from pathlib import Path

    tmp = Path(tempfile.mkdtemp())
    try:
        j = ChangesetJournal(tmp / "wal", fsync=False)
        bj = Broker(d, journal=j)
        bn = Broker(d)
        for b in (bj, bn):
            b.subscribe(exprs[0], CAPS, initial_target=tau0)
            b.subscribe(
                exprs[1], CAPS, initial_target=tau0, policy=PushPolicy.every(2)
            )
        stream = _stream(d, 4, seed=17)
        for i, (rm, ad) in enumerate(stream):
            got = bj.process_changeset(rm, ad)
            want = bn.process_changeset(rm, ad)
            assert_results_identical(got, want, ("step", i))
            assert bj._seq == bn._seq and bj._last_cid == bn._last_cid
            assert [s.since for s in bj.subs] == [s.since for s in bn.subs]
        got, want = bj.flush(), bn.flush()
        assert_results_identical(got, want, "flush")
        assert bj._seq == bn._seq
        assert bj.stats[-1].seq == bn.stats[-1].seq == bj._seq
        # the flush's committed fire is itself a journal record
        kinds = [r.kind for r in j.records()]
        assert kinds.count("subscribe") == 2
        assert kinds.count("ingest") == len(stream)
        assert kinds.count("fire") >= 1 and kinds[-1] == "fire"
        assert j.last_seq == bj._seq
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
