"""Broker golden tests: the fused multi-subscriber pass is bit-identical to
independent per-interest engine runs on the paper's running example
(Definitions 13-18, Examples 1-9), plus deterministic pattern-bank /
lane-routing checks including the >32-lane chunked path.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Broker,
    Dictionary,
    InterestExpr,
    IrapEngine,
    StepCapacities,
    build_pattern_bank,
    compile_interest,
    to_set,
)
from repro.kernels import ops, ref

A = "rdf:type"

CAPS = StepCapacities(n_removed=16, n_added=16, tau=64, rho=64, pulls=32)


@pytest.fixture()
def paper_setup():
    d = Dictionary()
    # Subscriber 1: the paper's running interest (Example 2)
    athlete = InterestExpr.parse(
        source="http://live.dbpedia.org/changesets",
        target="http://localhost:3030/athlete/sparql",
        bgp=[("?a", A, "dbo:Athlete"), ("?a", "dbp:goals", "?goals")],
        ogp=[("?a", "foaf:homepage", "?page")],
    )
    # Subscriber 2: shares the type pattern with subscriber 1 (bank dedup)
    types_only = InterestExpr.parse(
        source="http://live.dbpedia.org/changesets",
        target="http://localhost:3030/types/sparql",
        bgp=[("?a", A, "dbo:Athlete")],
    )
    # Subscriber 3: object-subject join, disjoint patterns
    teams = InterestExpr.parse(
        source="http://live.dbpedia.org/changesets",
        target="http://localhost:3030/teams/sparql",
        bgp=[("?x", "dbo:team", "?t"), ("?t", A, "dbo:Team")],
    )
    tau0 = [
        ("dbr:Marcel", A, "dbo:Athlete"),
        ("dbr:Cristiano_Ronaldo", A, "dbo:Athlete"),
        ("dbr:Cristiano_Ronaldo", "dbp:goals", "96"),
        ("dbr:Cristiano_Ronaldo", "foaf:homepage", '"http://cristianoronaldo.com"'),
    ]
    removed = [
        ("dbr:Marcel", "dbp:goals", "1"),
        ("dbr:Marcel", "dbo:team", "dbr:FNFT"),
        ("dbr:Tim%02", "foaf:name", '"Tim Berners-Lee"'),
        ("dbr:Cristiano_Ronaldo", "dbp:goals", "96"),
    ]
    added = [
        ("dbr:Cristiano_Ronaldo", "dbp:goals", "216"),
        ("dbr:Barack_Obama", "foaf:name", '"Barack Obama"'),
        ("dbr:Barack_Obama", "foaf:homepage", '"http://www.barackobama.com/"'),
        ("dbr:Rio_Ferdinand", A, "foaf:Person"),
        ("dbr:Rio_Ferdinand", A, "dbo:Athlete"),
        ("dbr:Rio_Ferdinand", "dbp:goals", "10"),
        ("dbr:Arvid_Smit", A, "dbo:Athlete"),
        ("dbr:FNFT", A, "dbo:Team"),
    ]
    return (
        d,
        [athlete, types_only, teams],
        d.encode_triples(tau0),
        d.encode_triples(removed),
        d.encode_triples(added),
    )


def assert_store_identical(got, want, label):
    assert np.array_equal(np.asarray(got.spo), np.asarray(want.spo)), label
    assert int(got.n) == int(want.n), label


def test_broker_parity_paper_example(paper_setup):
    """3 subscribers through the broker == 3 independent make_interest_step
    runs: r, r_i, r', a, a_i and the updated τ / ρ match exactly."""
    d, exprs, tau0, removed, added = paper_setup

    broker = Broker(d)
    for e in exprs:
        broker.subscribe(e, CAPS, initial_target=tau0)

    engine = IrapEngine(d)
    seed_subs = [
        engine.register_interest(e, CAPS, initial_target=tau0) for e in exprs
    ]

    # the shared rdf:type-Athlete pattern occupies one deduplicated lane
    assert broker.subs  # registration happened
    fused_outs = broker.process_changeset(removed, added)
    assert broker.bank.n_lanes < sum(s.plan.n_total for s in broker.subs)

    seed_outs = [s.apply(removed, added) for s in seed_subs]
    for k, (got, want) in enumerate(zip(fused_outs, seed_outs)):
        for field in ("r", "r_i", "r_prime", "a", "a_i"):
            assert_store_identical(
                getattr(got, field), getattr(want, field), (k, field)
            )
        assert bool(got.overflow) == bool(want.overflow)
        assert_store_identical(broker.subs[k].tau, seed_subs[k].tau, (k, "tau"))
        assert_store_identical(broker.subs[k].rho, seed_subs[k].rho, (k, "rho"))


def test_broker_parity_over_stream(paper_setup):
    """Parity holds across multiple changesets (ρ promotion included)."""
    d, exprs, tau0, removed, added = paper_setup
    broker = Broker(d)
    engine = IrapEngine(d)
    for e in exprs:
        broker.subscribe(e, CAPS, initial_target=tau0)
    seed_subs = [
        engine.register_interest(e, CAPS, initial_target=tau0) for e in exprs
    ]

    changesets = [
        (removed, added),
        (np.zeros((0, 3), np.int32),
         d.encode_triples([("dbr:Arvid_Smit", "dbp:goals", "3")])),
        (d.encode_triples([("dbr:Rio_Ferdinand", "dbp:goals", "10")]),
         np.zeros((0, 3), np.int32)),
    ]
    for d_np, a_np in changesets:
        fused_outs = broker.process_changeset(d_np, a_np)
        for k, sub in enumerate(seed_subs):
            want = sub.apply(d_np, a_np)
            got = fused_outs[k]
            for field in ("r", "r_i", "r_prime", "a", "a_i"):
                assert_store_identical(
                    getattr(got, field), getattr(want, field), (k, field)
                )
            assert_store_identical(broker.subs[k].tau, sub.tau, (k, "tau"))
            assert_store_identical(broker.subs[k].rho, sub.rho, (k, "rho"))


def test_broker_subscribe_midstream(paper_setup):
    """Subscribing after changesets have flowed re-banks and stays correct."""
    d, exprs, tau0, removed, added = paper_setup
    broker = Broker(d)
    broker.subscribe(exprs[0], CAPS, initial_target=tau0)
    broker.process_changeset(removed, added)
    rejits_before = broker.rejit_count

    broker.subscribe(exprs[2], CAPS)
    outs = broker.process_changeset(
        np.zeros((0, 3), np.int32),
        d.encode_triples([("dbr:X", "dbo:team", "dbr:FNFT")]),
    )
    assert broker.rejit_count == rejits_before + 1
    assert len(outs) == 2
    # new team edge is potentially interesting for the teams subscriber
    assert to_set(outs[1].a_i) == {
        tuple(int(x) for x in d.encode_triples(
            [("dbr:X", "dbo:team", "dbr:FNFT")])[0])
    }


def test_broker_per_subscriber_overflow_growth(paper_setup):
    """Overflow on one subscriber doubles only that subscriber's caps."""
    d, exprs, tau0, removed, added = paper_setup
    tiny = StepCapacities(n_removed=16, n_added=16, tau=4, rho=4, pulls=4)
    broker = Broker(d)
    broker.subscribe(exprs[0], tiny, initial_target=tau0)  # will overflow
    broker.subscribe(exprs[1], CAPS, initial_target=tau0)
    broker.process_changeset(removed, added)
    assert broker.subs[0].caps.tau > tiny.tau  # grew
    assert broker.subs[1].caps.tau == CAPS.tau  # untouched

    # and the grown state still matches an independent run
    engine = IrapEngine(d)
    sub = engine.register_interest(exprs[0], CAPS, initial_target=tau0)
    sub.apply(removed, added)
    assert to_set(broker.subs[0].tau) == to_set(sub.tau)
    assert to_set(broker.subs[0].rho) == to_set(sub.rho)


# ---------------------------------------------------------------------------
# pattern bank + lane routing (deterministic; hypothesis variants live in
# test_broker_properties.py)
# ---------------------------------------------------------------------------

def test_pattern_bank_dedup():
    d = Dictionary()
    e1 = InterestExpr.parse(
        "g", "t1", bgp=[("?a", A, "dbo:Athlete"), ("?a", "dbp:goals", "?g")]
    )
    e2 = InterestExpr.parse(
        "g", "t2", bgp=[("?b", A, "dbo:Athlete"), ("?b", "foaf:name", "?n")]
    )
    plans = [compile_interest(e, d) for e in (e1, e2)]
    bank = build_pattern_bank(plans)
    # "?x rdf:type dbo:Athlete" encodes identically for ?a and ?b -> shared
    assert bank.n_lanes == 3
    assert bank.lanes[0] == (0, 1)
    assert bank.lanes[1] == (0, 2)
    for k, plan in enumerate(plans):
        np.testing.assert_array_equal(
            bank.patterns[list(bank.lanes[k])], plan.patterns
        )


def test_lane_bits_roundtrip_chunked():
    """pattern_bitmask_words + lane_bits == per-plan pattern_bitmask, across
    a >32-lane bank (two bitset words)."""
    rng = np.random.default_rng(0)
    spo = jnp.asarray(rng.integers(0, 6, size=(64, 3)), jnp.int32)
    # 40 distinct patterns -> 2 words
    pats = np.full((40, 3), -1, np.int32)
    pats[:, 1] = np.arange(40) % 6
    pats[::3, 2] = np.arange(len(pats[::3])) % 6
    pats[5] = pats[37]  # duplicates collapse via the bank, not here
    bank_words = ops.pattern_bitmask_words(spo, jnp.asarray(pats))
    assert bank_words.shape == (64, 2)
    # a "plan" drawing lanes from both words, out of order
    lanes = (0, 37, 5, 33, 12, 39)
    local = ops.lane_bits(bank_words, lanes)
    want = ref.pattern_bitmask_ref(spo, jnp.asarray(pats[list(lanes)]))
    np.testing.assert_array_equal(np.asarray(local), np.asarray(want))


def test_broker_chunked_bank_parity():
    """>32 total bank lanes (chunked fused pass) stays bit-identical."""
    d = Dictionary()
    exprs = []
    for i in range(12):  # 12 interests x 3 distinct patterns = 36 lanes
        exprs.append(
            InterestExpr.parse(
                "g",
                f"t{i}",
                bgp=[(f"?a", A, f"cls:{i}"), (f"?a", f"p:{i}", "?v")],
                ogp=[(f"?a", f"q:{i}", "?w")],
            )
        )
    tau0 = d.encode_triples(
        [(f"e:{i}", A, f"cls:{i}") for i in range(12)]
        + [(f"e:{i}", f"q:{i}", f"w:{i}") for i in range(12)]
    )
    removed = d.encode_triples([(f"e:{i}", f"p:{i}", "x") for i in range(0, 12, 2)])
    added = d.encode_triples(
        [(f"e:{i}", f"p:{i}", "y") for i in range(12)]
        + [("e:junk", "p:junk", "z")]
    )
    caps = StepCapacities(n_removed=16, n_added=32, tau=64, rho=64, pulls=64)

    broker = Broker(d)
    for e in exprs:
        broker.subscribe(e, caps, initial_target=tau0)
    outs = broker.process_changeset(removed, added)
    assert broker.bank.n_lanes == 36 and broker.bank.n_words == 2

    engine = IrapEngine(d)
    for k, e in enumerate(exprs):
        sub = engine.register_interest(e, caps, initial_target=tau0)
        want = sub.apply(removed, added)
        for field in ("r", "r_i", "r_prime", "a", "a_i"):
            assert_store_identical(
                getattr(outs[k], field), getattr(want, field), (k, field)
            )
        assert_store_identical(broker.subs[k].tau, sub.tau, (k, "tau"))
        assert_store_identical(broker.subs[k].rho, sub.rho, (k, "rho"))
