"""Interest-based parameter-update propagation (beyond-paper, core/param_sync)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.param_sync import (
    ParamChangeset,
    ParamReplica,
    apply_changeset,
    diff_bank,
    filter_changeset,
)


def test_diff_and_apply_roundtrip():
    old = jnp.zeros((16, 8))
    new = old.at[jnp.array([3, 7, 11])].set(1.5)
    cs = diff_bank("experts", old, new)
    assert sorted(np.asarray(cs.rows).tolist()) == [3, 7, 11]
    rebuilt = apply_changeset(old, cs)
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(new))


def test_replica_receives_only_its_interest():
    rng = np.random.default_rng(0)
    n_experts, d = 32, 16
    source = jnp.asarray(rng.normal(size=(n_experts, d)), jnp.float32)
    my_experts = jnp.arange(0, n_experts, 2)  # subscribe to even experts
    replica = ParamReplica(
        banks={"experts": source},
        interests={"experts": my_experts},
    )
    # trainer updates a mix of subscribed + unsubscribed experts
    new = source.at[jnp.array([2, 3, 4, 5])].add(1.0)
    replica.receive(diff_bank("experts", source, new))

    got = np.asarray(replica.banks["experts"])
    want = np.asarray(new)
    for e in range(n_experts):
        if e in (2, 4):  # subscribed + updated -> synced
            np.testing.assert_array_equal(got[e], want[e])
        elif e in (3, 5):  # updated but NOT subscribed -> untouched
            np.testing.assert_array_equal(got[e], np.asarray(source)[e])
        else:
            np.testing.assert_array_equal(got[e], np.asarray(source)[e])
    # the filter shipped only half the offered bytes
    assert 0.4 < replica.savings < 0.6


def test_dense_bank_degenerates_to_mirror():
    source = jnp.zeros((4, 4))
    replica = ParamReplica(banks={"w": source}, interests={"w": None})
    new = source + 2.0
    replica.receive(diff_bank("w", source, new))
    np.testing.assert_array_equal(np.asarray(replica.banks["w"]), np.asarray(new))
    assert replica.savings == 0.0


def test_moe_expert_sync_end_to_end():
    """Trainer updates expert bank over steps; two replicas with disjoint
    expert interests stay consistent on their slices."""
    rng = np.random.default_rng(1)
    e, d = 8, 4
    bank = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
    r1 = ParamReplica({"experts": bank}, {"experts": jnp.arange(0, 4)})
    r2 = ParamReplica({"experts": bank}, {"experts": jnp.arange(4, 8)})
    cur = bank
    for step in range(5):
        upd = jnp.asarray(rng.normal(size=(e, d)) * (rng.random((e, 1)) < 0.4),
                          jnp.float32)
        new = cur + upd
        cs = diff_bank("experts", cur, new)
        r1.receive(cs)
        r2.receive(cs)
        cur = new
    np.testing.assert_array_equal(
        np.asarray(r1.banks["experts"])[:4], np.asarray(cur)[:4])
    np.testing.assert_array_equal(
        np.asarray(r2.banks["experts"])[4:], np.asarray(cur)[4:])
