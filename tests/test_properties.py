"""Property-based tests: jitted evaluator == pure-python oracle + invariants.

Small dense id universes force binding collisions; the fan-out cap K is sized
above the maximum possible τ fan-out so the capped evaluator is exact
(DESIGN.md §1).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
)
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    Dictionary,
    InterestExpr,
    StepCapacities,
    from_array,
    make_interest_step,
    to_set,
)
from repro.core.evaluation import build_index, make_side_evaluator
from repro.core.interest import compile_interest
from repro.core.oracle import OracleEvaluator
from repro.core.triples import (
    apply_changeset,
    difference,
    from_numpy,
    intersection,
    union,
)

# ---------------------------------------------------------------------------
# fixed mini-universe: subjects s0..s5, predicates p0..p3 + type, objects/classes
# ---------------------------------------------------------------------------
DICT = Dictionary()
TERMS = (
    [f"s{i}" for i in range(6)]
    + ["type", "p0", "p1", "p2", "goals", "label"]
    + [f"o{i}" for i in range(6)]
    + ["Athlete", "Team"]
)
for t in TERMS:
    DICT.encode_term(t)
R_CAP = DICT.id_capacity
K = 8  # >= max τ fan-out given <=8-row τ sets below

PLANS = {
    "star2": InterestExpr.parse(
        "g", "t",
        bgp=[("?a", "type", "Athlete"), ("?a", "goals", "?g")],
    ),
    "star2_ogp": InterestExpr.parse(
        "g", "t",
        bgp=[("?a", "type", "Athlete"), ("?a", "goals", "?g")],
        ogp=[("?a", "p0", "?h")],
    ),
    "single": InterestExpr.parse("g", "t", bgp=[("?a", "goals", "?g")]),
    "football": InterestExpr.parse(
        "g", "t",
        bgp=[
            ("?f", "type", "Athlete"),
            ("?f", "p1", "?t"),
            ("?t", "label", "?n"),
        ],
    ),
    "object_root": InterestExpr.parse(
        "g", "t",
        bgp=[("?x", "p0", "?a"), ("?a", "type", "Athlete")],
    ),
}
COMPILED = {k: compile_interest(e, DICT) for k, e in PLANS.items()}
ORACLES = {k: OracleEvaluator(p) for k, p in COMPILED.items()}
M_CAP, OUT_CAP, PULL_CAP = 16, 64, 4096
EVALS = {
    k: make_side_evaluator(
        p, id_capacity=R_CAP, fanout=K, out_capacity=OUT_CAP,
        pull_capacity=PULL_CAP,
    )
    for k, p in COMPILED.items()
}
CAPS = StepCapacities(n_removed=M_CAP, n_added=M_CAP, tau=64, rho=64,
                      pulls=PULL_CAP, fanout=K)
STEPS = {
    k: make_interest_step(p, id_capacity=R_CAP, caps=CAPS)
    for k, p in COMPILED.items()
}

SUBJ = [DICT.lookup(f"s{i}") for i in range(6)]
PRED = [DICT.lookup(x) for x in ("type", "p0", "p1", "goals", "label")]
OBJ = [DICT.lookup(x) for x in ("Athlete", "Team", "o0", "o1", "o2")] + SUBJ[:3]


def triple_strategy():
    return st.tuples(
        st.sampled_from(SUBJ), st.sampled_from(PRED), st.sampled_from(OBJ)
    )


def triple_set(max_size):
    return st.sets(triple_strategy(), max_size=max_size)


def np_rows(tris):
    if not tris:
        return np.zeros((0, 3), np.int32)
    return np.asarray(sorted(tris), np.int32)


HSETTINGS = settings(
    max_examples=60,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


@given(
    plan_key=st.sampled_from(sorted(PLANS)),
    m=triple_set(10),
    tau=triple_set(8),
)
@HSETTINGS
def test_side_evaluation_matches_oracle(plan_key, m, tau):
    ev = EVALS[plan_key]
    orc = ORACLES[plan_key]
    m_store = from_numpy(np_rows(m), M_CAP)
    tau_store = from_numpy(np_rows(tau), 64)
    res = ev(m_store, build_index(tau_store))
    o_inter, o_pot, o_pulls = orc.evaluate_side(set(m), set(tau))
    assert to_set(res.interesting) == o_inter, plan_key
    assert to_set(res.potential) == o_pot, plan_key
    assert to_set(res.pulls) == o_pulls, plan_key
    assert not bool(res.overflow)
    # partition invariants (Defs 8-10): interesting/potential ⊆ M, disjoint
    assert o_inter <= m and o_pot <= m and not (o_inter & o_pot)


@given(
    plan_key=st.sampled_from(sorted(PLANS)),
    d_set=triple_set(8),
    a_set=triple_set(8),
    tau=triple_set(8),
    rho=triple_set(6),
)
@HSETTINGS
def test_full_step_matches_oracle(plan_key, d_set, a_set, tau, rho):
    step = STEPS[plan_key]
    orc = ORACLES[plan_key]
    tau1, rho1, out = step(
        from_numpy(np_rows(d_set), M_CAP),
        from_numpy(np_rows(a_set), M_CAP),
        from_numpy(np_rows(tau), 64),
        from_numpy(np_rows(rho), 64),
    )
    o = orc.step(set(d_set), set(a_set), set(tau), set(rho))
    assert not bool(out.overflow)
    assert to_set(out.r) == o["r"], plan_key
    assert to_set(out.r_i) == o["r_i"], plan_key
    assert to_set(out.r_prime) == o["r_prime"], plan_key
    assert to_set(out.a) == o["a"], plan_key
    assert to_set(out.a_i) == o["a_i"], plan_key
    assert to_set(tau1) == o["tau1"], plan_key
    assert to_set(rho1) == o["rho1"], plan_key
    # τ and ρ stay disjoint-by-role: promoted triples must leave ρ
    assert not (to_set(rho1) & o["a"])


@given(plan_key=st.sampled_from(sorted(PLANS)), tau=triple_set(8), rho=triple_set(6))
@HSETTINGS
def test_empty_changeset_is_identity(plan_key, tau, rho):
    """Identity holds for *reachable* ρ states (no parked full matches —
    α over I = A ∪ ρ legitimately promotes those even when A = ∅)."""
    orc = ORACLES[plan_key]
    promoted, _, _ = orc.evaluate_side(set(rho), set(tau))
    rho = rho - promoted
    step = STEPS[plan_key]
    z = from_numpy(np.zeros((0, 3), np.int32), M_CAP)
    tau1, rho1, out = step(
        z, z, from_numpy(np_rows(tau), 64), from_numpy(np_rows(rho), 64)
    )
    assert to_set(tau1) == tau
    assert to_set(rho1) == rho
    assert int(out.r.n) == 0 and int(out.a.n) == 0


@given(a=triple_set(20), b=triple_set(20))
@HSETTINGS
def test_set_algebra_matches_python(a, b):
    sa = from_numpy(np_rows(a), 32)
    sb = from_numpy(np_rows(b), 32)
    u, ovf = union(sa, sb, 64)
    assert to_set(u) == a | b and not bool(ovf)
    assert to_set(difference(sa, sb)) == a - b
    assert to_set(intersection(sa, sb)) == a & b


@given(v=triple_set(20), d_set=triple_set(10), a_set=triple_set(10))
@HSETTINGS
def test_changeset_application_def6(v, d_set, a_set):
    """υ(V, Δ) = (V \\ D) ∪ A — Definition 6."""
    sv = from_numpy(np_rows(v), 64)
    sd = from_numpy(np_rows(d_set), 16)
    sa = from_numpy(np_rows(a_set), 16)
    v1, ovf = apply_changeset(sv, sd, sa)
    assert to_set(v1) == (v - d_set) | a_set
    assert not bool(ovf)


@given(a=triple_set(30))
@HSETTINGS
def test_union_overflow_flag(a):
    sa = from_numpy(np_rows(a), 32)
    small_cap = max(1, len(a) - 1) if a else 1
    u, ovf = union(sa, sa, small_cap)
    assert bool(ovf) == (len(a) > small_cap)


def test_replica_consistency_over_stream():
    """Mirror-equivalence: for an all-matching interest, iRap == full mirror."""
    d = Dictionary()
    expr = InterestExpr.parse("g", "t", bgp=[("?s", "?p", "?o")])
    plan = compile_interest(expr, d)
    # a single all-wildcard pattern: everything is interesting
    caps = StepCapacities(n_removed=16, n_added=16, tau=128, rho=64, pulls=64)
    step = make_interest_step(plan, id_capacity=64, caps=caps)
    rng = np.random.default_rng(0)
    tau = from_numpy(np.zeros((0, 3), np.int32), 128)
    rho = from_numpy(np.zeros((0, 3), np.int32), 64)
    mirror: set = set()
    for _ in range(6):
        d_rows = rng.integers(0, 8, size=(rng.integers(0, 6), 3)).astype(np.int32)
        a_rows = rng.integers(0, 8, size=(rng.integers(0, 8), 3)).astype(np.int32)
        tau, rho, out = step(
            from_numpy(np.unique(d_rows, axis=0), 16),
            from_numpy(np.unique(a_rows, axis=0), 16),
            tau,
            rho,
        )
        mirror = (mirror - {tuple(r) for r in d_rows.tolist()}) | {
            tuple(r) for r in a_rows.tolist()
        }
        assert to_set(tau) == mirror
        assert int(rho.n) == 0


@given(
    plan_key=st.sampled_from(sorted(PLANS)),
    m=triple_set(10),
    tau=triple_set(8),
)
@HSETTINGS
def test_candidate_dedup_preserves_semantics(plan_key, m, tau):
    """§Perf HC-C: the dedup'd probe pools are a pure optimization."""
    ev = make_side_evaluator(
        COMPILED[plan_key], id_capacity=R_CAP, fanout=K,
        out_capacity=OUT_CAP, pull_capacity=PULL_CAP, dedup_candidates=64,
    )
    m_store = from_numpy(np_rows(m), M_CAP)
    tau_store = from_numpy(np_rows(tau), 64)
    res = ev(m_store, build_index(tau_store))
    base = EVALS[plan_key](m_store, build_index(tau_store))
    assert to_set(res.interesting) == to_set(base.interesting)
    assert to_set(res.potential) == to_set(base.potential)
    assert to_set(res.pulls) == to_set(base.pulls)
    assert not bool(res.overflow)
