"""Push-scheduler tests: per-subscriber cadences over the fused pass.

Scheduled (every-k / priority / max-staleness) outputs must stay
bit-identical to eagerly evaluating the same composed changesets per
subscriber, deferral must not touch a subscriber's τ/ρ, and flush()
drains pending batches. Also covers the Definition-6 changeset
composition algebra the scheduler batches with.
"""
import numpy as np
import pytest

from repro.core import (
    Broker,
    Dictionary,
    InterestExpr,
    IrapEngine,
    PushPolicy,
    StepCapacities,
    apply_changeset,
    compose_changesets,
    from_numpy,
    to_set,
)
from repro.core.propagation import ChangesetBatch

A = "rdf:type"
CAPS = StepCapacities(n_removed=16, n_added=16, tau=64, rho=64, pulls=32)


@pytest.fixture()
def setting():
    d = Dictionary()
    expr = InterestExpr.parse(
        "g", "t", bgp=[("?a", A, "c:Athlete"), ("?a", "p:goals", "?v")]
    )
    tau0 = d.encode_triples(
        [("e:1", A, "c:Athlete"), ("e:1", "p:goals", "10")]
    )
    changesets = [
        (
            d.encode_triples([("e:1", "p:goals", "10")]),
            d.encode_triples([("e:1", "p:goals", "11"), ("e:2", A, "c:Athlete")]),
        ),
        (
            np.zeros((0, 3), np.int32),
            d.encode_triples([("e:2", "p:goals", "4"), ("e:3", "p:x", "y")]),
        ),
        (
            d.encode_triples([("e:2", "p:goals", "4"), ("e:1", "p:goals", "11")]),
            d.encode_triples([("e:1", "p:goals", "12")]),
        ),
        (
            d.encode_triples([("e:2", A, "c:Athlete")]),
            d.encode_triples([("e:4", A, "c:Athlete"), ("e:4", "p:goals", "0")]),
        ),
    ]
    return d, expr, tau0, changesets


def composed(changesets, cap=256):
    """Fold raw changesets into one batch via the Definition-6 algebra."""
    batch = ChangesetBatch.fresh(*changesets[0], 1)
    for i, cs in enumerate(changesets[1:], start=2):
        batch.extend(*cs, i)
    return batch.arrays()


def assert_outputs_identical(got, want, label):
    for field in ("r", "r_i", "r_prime", "a", "a_i"):
        got_f, want_f = getattr(got, field), getattr(want, field)
        assert np.array_equal(
            np.asarray(got_f.spo), np.asarray(want_f.spo)
        ), (label, field)


def test_compose_changesets_matches_sequential_apply():
    """<D1∪D2, (A1\\D2)∪A2> applied once == the two changesets in order."""
    rng = np.random.default_rng(3)
    for trial in range(8):
        def rows(n):
            return np.unique(
                rng.integers(0, 5, size=(n, 3)).astype(np.int32), axis=0
            )

        base = from_numpy(rows(10), 64)
        d1, a1 = from_numpy(rows(4), 16), from_numpy(rows(4), 16)
        d2, a2 = from_numpy(rows(4), 16), from_numpy(rows(4), 16)
        seq, _ = apply_changeset(base, d1, a1)
        seq, _ = apply_changeset(seq, d2, a2)
        d12, a12, ovf = compose_changesets(d1, a1, d2, a2, 64)
        assert not bool(ovf)
        once, _ = apply_changeset(base, d12, a12)
        assert to_set(once) == to_set(seq), trial


def test_every_k_matches_eager_composed_batches(setting):
    """An every-2 subscriber fires on cs2/cs4 with the composed batches and
    matches an engine fed exactly those batches; the eager subscriber keeps
    per-changeset parity throughout."""
    d, expr, tau0, changesets = setting
    broker = Broker(d)
    eager = broker.subscribe(expr, CAPS, initial_target=tau0)
    slow = broker.subscribe(
        expr, CAPS, initial_target=tau0, policy=PushPolicy.every(2)
    )

    engine = IrapEngine(d)
    ref_eager = engine.register_interest(expr, CAPS, initial_target=tau0)
    ref_slow = engine.register_interest(expr, CAPS, initial_target=tau0)

    for i, cs in enumerate(changesets):
        outs = broker.process_changeset(*cs)
        want = ref_eager.apply(*cs)
        assert_outputs_identical(outs[0], want, ("eager", i))
        if i % 2 == 0:  # cs1 / cs3: deferred — no evaluation, no state change
            assert outs[1] is None
            assert broker.stats[-1].n_deferred == 1
        else:  # cs2 / cs4: fires with the composed pending batch
            want_slow = ref_slow.apply(*composed(changesets[i - 1 : i + 1]))
            assert_outputs_identical(outs[1], want_slow, ("slow", i))
    assert to_set(slow.tau) == to_set(ref_slow.tau)
    assert to_set(slow.rho) == to_set(ref_slow.rho)
    assert to_set(eager.tau) == to_set(ref_eager.tau)


def test_priority_lane_is_eager_and_first(setting):
    d, expr, tau0, changesets = setting
    broker = Broker(d)
    broker.subscribe(
        expr, CAPS, initial_target=tau0, policy=PushPolicy.priority_lane()
    )
    engine = IrapEngine(d)
    ref = engine.register_interest(expr, CAPS, initial_target=tau0)
    for i, cs in enumerate(changesets):
        outs = broker.process_changeset(*cs)
        assert outs[0] is not None
        assert_outputs_identical(outs[0], ref.apply(*cs), ("priority", i))
        assert broker.stats[-1].n_evaluated == 1


def test_max_staleness_defers_until_flush(setting):
    """A pure staleness policy with a huge bound never fires on its own;
    flush() drains the whole pending batch in one evaluation."""
    d, expr, tau0, changesets = setting
    broker = Broker(d)
    lazy = broker.subscribe(
        expr, CAPS, initial_target=tau0, policy=PushPolicy.max_staleness(1e9)
    )
    for cs in changesets[:3]:
        outs = broker.process_changeset(*cs)
        assert outs[0] is None
    assert int(lazy.tau.n) == 2  # untouched since init

    flushed = broker.flush()
    engine = IrapEngine(d)
    ref = engine.register_interest(expr, CAPS, initial_target=tau0)
    want = ref.apply(*composed(changesets[:3]))
    assert_outputs_identical(flushed[0], want, "flush")
    assert to_set(lazy.tau) == to_set(ref.tau)
    assert to_set(lazy.rho) == to_set(ref.rho)
    # nothing pending anymore: flush is a no-op
    assert broker.flush() == [None]


def test_max_staleness_zero_fires_every_changeset(setting):
    d, expr, tau0, changesets = setting
    broker = Broker(d)
    broker.subscribe(
        expr, CAPS, initial_target=tau0, policy=PushPolicy.max_staleness(0.0)
    )
    engine = IrapEngine(d)
    ref = engine.register_interest(expr, CAPS, initial_target=tau0)
    for i, cs in enumerate(changesets[:2]):
        outs = broker.process_changeset(*cs)
        assert_outputs_identical(outs[0], ref.apply(*cs), ("stale0", i))


def test_flush_single_subscriber(setting):
    """flush(subs=[one]) drains only that subscriber's pending batch."""
    d, expr, tau0, changesets = setting
    broker = Broker(d)
    s1 = broker.subscribe(
        expr, CAPS, initial_target=tau0, policy=PushPolicy.every(3)
    )
    s2 = broker.subscribe(
        expr, CAPS, initial_target=tau0, policy=PushPolicy.every(3)
    )
    broker.process_changeset(*changesets[0])
    flushed = broker.flush(subs=[s1])
    assert flushed[0] is not None and flushed[1] is None

    engine = IrapEngine(d)
    ref = engine.register_interest(expr, CAPS, initial_target=tau0)
    want = ref.apply(*changesets[0])
    assert_outputs_identical(flushed[0], want, "single flush")
    assert to_set(s1.tau) == to_set(ref.tau)
    assert int(s2.tau.n) == 2  # still pending
    # s2 later drains the same (still retained) batch plus the next one
    broker.process_changeset(*changesets[1])
    out2 = broker.flush(subs=[s2])[1]
    ref2 = IrapEngine(d).register_interest(expr, CAPS, initial_target=tau0)
    want2 = ref2.apply(*composed(changesets[:2]))
    assert_outputs_identical(out2, want2, "catch-up flush")
    assert to_set(s2.tau) == to_set(ref2.tau)
