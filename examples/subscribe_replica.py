"""Streaming subscription driver: two interests over a synthetic DBpedia-Live.

Maintains the Football and Location replicas against a live changeset stream
and prints per-changeset propagation stats (the iRap architecture of paper
§3: Interest Manager + Changeset Manager + Interest Evaluator loop).

    PYTHONPATH=src python examples/subscribe_replica.py --days 3
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.core import IrapEngine

from benchmarks.common import (
    FOOTBALL,
    LOCATION,
    default_generator,
    football_caps,
    location_caps,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=3)
    ap.add_argument("--per-day", type=int, default=3)
    ap.add_argument("--scale", type=float, default=0.5)
    args = ap.parse_args()

    gen = default_generator(seed=7, scale=args.scale)
    gen.initial_dump()
    engine = IrapEngine(gen.dict)
    fb = engine.register_interest(
        FOOTBALL, football_caps(),
        initial_target=gen.slice_for(
            lambda t: t[0].startswith(("dbr:Athlete", "dbr:Team"))),
    )
    loc = engine.register_interest(
        LOCATION, location_caps(), initial_target=gen.slice_for(lambda t: True)
    )
    print(f"source: {len(gen.current)} triples | football τ0={int(fb.tau.n)} "
          f"| location τ0={int(loc.tau.n)}")

    cs_id = 0
    for day in range(args.days):
        for _ in range(args.per_day):
            cs_id += 1
            d_np, a_np = gen.changeset()
            stats = engine.process_changeset(d_np, a_np)
            f, l = stats
            print(
                f"[day {day+1} cs {cs_id}] Δ=({d_np.shape[0]}-,{a_np.shape[0]}+) | "
                f"football: r={f.interesting_removed} a={f.interesting_added} "
                f"ρ={f.potential_size} τ={f.target_size} ({f.elapsed_s*1e3:.0f} ms) | "
                f"location: r={l.interesting_removed} a={l.interesting_added} "
                f"ρ={l.potential_size} τ={l.target_size} ({l.elapsed_s*1e3:.0f} ms)"
            )
    print("\nfinal sizes:",
          f"source={len(gen.current)} football_tau={int(fb.tau.n)}",
          f"location_tau={int(loc.tau.n)}")


if __name__ == "__main__":
    main()
