"""Quickstart: the paper's running example (Examples 1-9) end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    Dictionary,
    InterestExpr,
    IrapEngine,
    StepCapacities,
    to_numpy,
)

A = "rdf:type"


def show(d, title, store_or_out):
    print(f"\n== {title} ==")
    for s, p, o in d.decode_triples(to_numpy(store_or_out)):
        print(f"  {s} {p} {o} .")


def main():
    d = Dictionary()
    # Example 2: interest in athletes with goals, optionally a homepage
    expr = InterestExpr.parse(
        source="http://live.dbpedia.org/changesets",
        target="http://localhost:3030/target/sparql",
        bgp=[("?a", A, "dbo:Athlete"), ("?a", "dbp:goals", "?goals")],
        ogp=[("?a", "foaf:homepage", "?page")],
    )
    tau0 = d.encode_triples([
        ("dbr:Marcel", A, "dbo:Athlete"),
        ("dbr:Cristiano_Ronaldo", A, "dbo:Athlete"),
        ("dbr:Cristiano_Ronaldo", "dbp:goals", "96"),
        ("dbr:Cristiano_Ronaldo", "foaf:homepage", '"http://cristianoronaldo.com"'),
    ])
    engine = IrapEngine(d)
    sub = engine.register_interest(
        expr,
        StepCapacities(n_removed=16, n_added=16, tau=64, rho=64, pulls=64),
        initial_target=tau0,
    )

    # Example 1: the changeset
    removed = d.encode_triples([
        ("dbr:Marcel", "dbp:goals", "1"),
        ("dbr:Marcel", "dbo:team", "dbr:FNFT"),
        ("dbr:Tim%02", "foaf:name", '"Tim Berners-Lee"'),
        ("dbr:Cristiano_Ronaldo", "dbp:goals", "96"),
    ])
    added = d.encode_triples([
        ("dbr:Cristiano_Ronaldo", "dbp:goals", "216"),
        ("dbr:Barack_Obama", "foaf:name", '"Barack Obama"'),
        ("dbr:Barack_Obama", "foaf:homepage", '"http://www.barackobama.com/"'),
        ("dbr:Rio_Ferdinand", A, "foaf:Person"),
        ("dbr:Rio_Ferdinand", A, "dbo:Athlete"),
        ("dbr:Rio_Ferdinand", "dbp:goals", "10"),
        ("dbr:Arvid_Smit", A, "dbo:Athlete"),
    ])

    out = sub.apply(removed, added)
    show(d, "interesting removed  r  (Example 5)", out.r)
    show(d, "moved to ρ            r' (Example 5)", out.r_prime)
    show(d, "interesting added    a  (Example 6)", out.a)
    show(d, "potentially added    a_i (Example 6)", out.a_i)
    show(d, "resulting target τ   (Listing 1.3)", sub.tau)
    show(d, "potential dataset ρ  (Listing 1.4)", sub.rho)

    # a later changeset promotes Arvid out of ρ
    out2 = sub.apply(
        np.zeros((0, 3), np.int32),
        d.encode_triples([("dbr:Arvid_Smit", "dbp:goals", "3")]),
    )
    show(d, "second changeset: promoted adds", out2.a)
    show(d, "ρ after promotion", sub.rho)


if __name__ == "__main__":
    main()
