"""Multi-subscriber broker demo: many interests, cohort-cached fused passes.

Registers several subscribers (the paper-shaped Football interest plus a
family of class-star interests) against one synthetic DBpedia-Live stream —
contrast with examples/subscribe_replica.py, which drives the per-interest
engine. Subscribers carry different PushPolicy cadences (an eager priority
lane, every-k batchers, a staleness-bounded replica), mid-stream churn
(unsubscribe + re-subscribe) shows the cohort executable cache absorbing
membership changes without global re-jits, and a final flush() drains every
deferred batch.

    PYTHONPATH=src python examples/multi_subscriber.py --days 3 --subscribers 6
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.core import Broker, InterestExpr, PushPolicy, StepCapacities

from benchmarks.common import FOOTBALL, default_generator, football_caps


def class_interest(i: int) -> InterestExpr:
    """Subscriber i mirrors one entity class + its names (same plan shape
    for every i, so the broker evaluates all of them as one vmapped cohort)."""
    cls = ["dbo:SoccerPlayer", "dbo:Place", "dbo:Person"][i % 3]
    return InterestExpr.parse(
        source="synthetic://dbpedia-live",
        target=f"local://class{i}",
        bgp=[("?e", "rdf:type", cls), ("?e", "foaf:name", "?name")],
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=3)
    ap.add_argument("--per-day", type=int, default=3)
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--subscribers", type=int, default=6)
    args = ap.parse_args()

    gen = default_generator(seed=7, scale=args.scale)
    gen.initial_dump()
    broker = Broker(gen.dict)

    # the paper interest rides a priority lane: evaluated at every changeset,
    # ahead of the batched class subscribers
    broker.subscribe(
        FOOTBALL, football_caps(),
        initial_target=gen.slice_for(
            lambda t: t[0].startswith(("dbr:Athlete", "dbr:Team"))),
        policy=PushPolicy.priority_lane(),
    )
    caps = StepCapacities(
        n_removed=1024, n_added=2048, tau=1 << 14, rho=1 << 13, pulls=1 << 12,
        fanout=8, dedup_candidates=1024,
    )
    policies = [
        PushPolicy(),  # eager default
        PushPolicy.every(2),  # slow consumer: batch 2 changesets per push
        PushPolicy.max_staleness(3600.0),  # mirror: drained by flush() below
    ]
    for i in range(args.subscribers - 1):
        broker.subscribe(class_interest(i), caps, policy=policies[i % 3])

    print(f"source: {len(gen.current)} triples | subscribers: "
          f"{len(broker.subs)}")

    cs_id = 0
    churned = False
    for day in range(args.days):
        for _ in range(args.per_day):
            cs_id += 1
            d_np, a_np = gen.changeset()
            outs = broker.process_changeset(d_np, a_np)
            st = broker.stats[-1]
            per_sub = " ".join(
                f"s{k}:r={int(o.r.n)},a={int(o.a.n)}" if o is not None
                else f"s{k}:…"  # policy deferred: batch keeps accumulating
                for k, o in enumerate(outs)
            )
            print(
                f"[day {day+1} cs {cs_id}] Δ=({d_np.shape[0]}-,{a_np.shape[0]}+) "
                f"bank={st.n_lanes}/{st.n_lanes_raw} lanes "
                f"eval={st.n_evaluated}/{len(broker.subs)} "
                f"({st.elapsed_s*1e3:.0f} ms, {st.rejit_s*1e3:.0f} ms re-jit) "
                f"| {per_sub}"
            )
        if not churned and len(broker.subs) > 2:
            # mid-stream churn: drop one class subscriber, add a fresh one —
            # only the touched cohort can recompile, everyone else reuses
            # cached executables
            compiles_before = broker.rejit_count
            broker.unsubscribe(broker.subs[-1])
            broker.subscribe(
                class_interest(args.subscribers), caps, policy=PushPolicy()
            )
            churned = True
            print(f"  ~ churn: -1/+1 subscriber (compiles so far: "
                  f"{compiles_before}, bank {broker.bank.n_live} live / "
                  f"{broker.bank.n_lanes} lanes)")

    flushed = broker.flush()
    n_drained = sum(1 for o in flushed if o is not None)
    print(f"\nflush(): drained {n_drained} deferred subscriber(s)")
    print("final τ sizes:",
          " ".join(f"s{k}={int(s.tau.n)}" for k, s in enumerate(broker.subs)),
          f"| executable compiles: {broker.rejit_count} "
          f"(cohorts: {sum(broker.cohort_compiles.values())})")


if __name__ == "__main__":
    main()
