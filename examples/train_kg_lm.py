"""End-to-end driver: evolving KG -> interest-filtered replica -> LM training.

The full production loop (DESIGN.md §4): the synthetic source publishes
changesets, the iRap subscription keeps the Football replica consistent, the
verbalizer turns replica triples into token streams, and the fault-tolerant
Trainer (checkpoint/restart, straggler detection) fits a decoder LM on them
— refreshing the pipeline whenever the replica changes.

    PYTHONPATH=src python examples/train_kg_lm.py --steps 60
    PYTHONPATH=src python examples/train_kg_lm.py --steps 300 --width 768 \
        --layers 12   # ~100M-param configuration
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import numpy as np

from benchmarks.common import FOOTBALL, default_generator, football_caps
from repro.core import IrapEngine
from repro.data import ReplicaTokenPipeline, Verbalizer
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim import AdamW, cosine_warmup
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default="/tmp/irap_train_ckpt")
    ap.add_argument("--refresh-every", type=int, default=25,
                    help="apply one changeset + refresh pipeline every N steps")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="kg-lm", family="dense", n_layers=args.layers,
        d_model=args.width, n_heads=max(4, args.width // 64),
        n_kv_heads=max(2, args.width // 128), d_head=64,
        d_ff=args.width * 4, vocab=args.vocab,
    )
    api = build_model(cfg)
    print(f"model: {cfg.n_params/1e6:.1f} M params")

    # data plane: generator -> subscription -> verbalizer -> pipeline
    gen = default_generator(seed=11, scale=1.0)
    gen.initial_dump()
    engine = IrapEngine(gen.dict)
    sub = engine.register_interest(
        FOOTBALL, football_caps(),
        initial_target=gen.slice_for(
            lambda t: t[0].startswith(("dbr:Athlete", "dbr:Team"))),
    )
    verb = Verbalizer(vocab=args.vocab, dictionary=gen.dict)
    pipe = ReplicaTokenPipeline(verb, batch_size=args.batch, seq_len=args.seq)
    pipe.refresh(sub.tau)
    print(f"replica τ: {int(sub.tau.n)} triples")

    state = {"n": 0}

    def data():
        while True:
            state["n"] += 1
            if state["n"] % args.refresh_every == 0:
                d_np, a_np = gen.changeset()
                out = sub.apply(d_np, a_np)
                pipe.refresh(sub.tau)
                print(f"  [changeset] +{int(out.a.n)} interesting, "
                      f"τ={int(sub.tau.n)} — pipeline refreshed")
            yield next(pipe)

    opt = AdamW(
        learning_rate=cosine_warmup(3e-3, 20, args.steps),
        weight_decay=0.01, max_grad_norm=1.0,
    )

    def init_state():
        params = api.init(jax.random.key(0))
        return params, opt.init(params)

    tr = Trainer(
        make_train_step(api, opt), init_state, data(),
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=20),
    )
    print(f"starting at step {tr.step}")
    t0 = time.time()
    hist = tr.run(args.steps, inject_failure_at=args.inject_failure_at)
    dt = time.time() - t0
    print(f"\ntrained {len(hist)} steps in {dt:.1f}s "
          f"({dt/len(hist):.2f} s/step)")
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    tr.save()


if __name__ == "__main__":
    main()
