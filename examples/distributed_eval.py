"""Distributed interest evaluation demo: shard_map semijoin over 8 devices.

Forces 8 host devices (must run as its own process) and evaluates the
Football interest over hash-partitioned changeset/target shards, with
all_to_all-routed candidate-assertion probes (DESIGN.md §3).

    PYTHONPATH=src python examples/distributed_eval.py
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FOOTBALL, default_generator
from repro.core.distributed import (
    gather_result_sets,
    make_distributed_evaluator,
    make_mesh_compat,
    partition_rows,
    prepare_target_shards,
)
from repro.core.interest import compile_interest


def main():
    n_shards = 8
    mesh = make_mesh_compat((n_shards,), ("data",))
    gen = default_generator(seed=5, scale=0.5)
    gen.initial_dump()
    tau_rows = gen.slice_for(
        lambda t: t[0].startswith(("dbr:Athlete", "dbr:Team")))
    plan = compile_interest(FOOTBALL, gen.dict)

    m_cap, t_cap = 1024, 4096
    ev = make_distributed_evaluator(
        plan, mesh, id_capacity=gen.dict.id_capacity, fanout=8,
        out_capacity=2048, pull_capacity=8192,
    )
    spo_sh, ops_sh, tau_ovf = prepare_target_shards(tau_rows, n_shards, t_cap)

    for i in range(3):
        d_np, a_np = gen.changeset()
        m_sh, m_ovf = partition_rows(a_np, n_shards, key_col=0, cap=m_cap)
        t0 = time.perf_counter()
        res = ev(jnp.asarray(m_sh), jnp.asarray(spo_sh), jnp.asarray(ops_sh))
        jax.block_until_ready(res.interesting.spo)
        dt = time.perf_counter() - t0
        inter, pot, pulls, overflow = gather_result_sets(
            res, partition_overflow=m_ovf | tau_ovf
        )
        per_shard = [int(x) for x in np.asarray(res.interesting.n)]
        print(
            f"[changeset {i+1}] adds={a_np.shape[0]} -> interesting={len(inter)} "
            f"potential={len(pot)} pulls={len(pulls)} overflow={overflow} "
            f"in {dt*1e3:.0f} ms (per-shard interesting: {per_shard})"
        )
    print("\n8-way shard_map evaluation with all_to_all-routed probes: OK")


if __name__ == "__main__":
    main()
