"""Model assembly for the six architecture families.

Every family provides the same API (``ModelApi``):
  init(rng)                         -> params pytree (stacked layer dims)
  train_loss(params, batch)         -> (loss, metrics)
  prefill(params, batch)            -> (last_logits, cache)
  decode_step(params, cache, tok, pos) -> (logits, cache)
  init_cache(batch_size, max_seq)   -> cache pytree

Layer stacks run under ``lax.scan`` over stacked params (compile-time sanity
at 60-100 layers); heterogeneous archs (gemma3 5:1 local:global, zamba2
shared-attn, vision cross-attn) scan over structurally identical *groups*
with a tail segment (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from . import ssm as S
from .config import ModelConfig



# Layer-stack scans honor a module-level unroll flag: the dry-run's probe
# compiles unroll them so XLA cost analysis sees every trip (a while-loop
# body is otherwise counted once — see launch/dryrun.py).
SCAN_UNROLL = False


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=SCAN_UNROLL)


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


# ---------------------------------------------------------------------------
# shared machinery
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


def init_embeddings(key, cfg: ModelConfig):
    vp, d = cfg.padded_vocab, cfg.d_model
    k1, k2 = jax.random.split(key)
    p = {
        "embed": L._normal(k1, (vp, d), 1.0, cfg.param_dtype),
        "final_norm": L.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L._normal(k2, (d, vp), 1.0 / np.sqrt(d), cfg.param_dtype)
    return p


def embed(params, tokens, cfg: ModelConfig):
    return jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)


_VOCAB_MASK_CACHE: Dict[Tuple[int, int], np.ndarray] = {}


def _vocab_pad_bias(cfg: ModelConfig):
    key = (cfg.vocab, cfg.padded_vocab)
    if key not in _VOCAB_MASK_CACHE:
        m = np.zeros((cfg.padded_vocab,), np.float32)
        m[cfg.vocab :] = L.NEG_INF
        _VOCAB_MASK_CACHE[key] = m
    return _VOCAB_MASK_CACHE[key]


def unembed(params, x, cfg: ModelConfig):
    x = L.apply_norm(params["final_norm"], x, cfg)
    w = params.get("unembed")
    if w is None:
        w = params["embed"].T
    logits = jnp.einsum("...d,dv->...v", x, w.astype(cfg.dtype))
    return logits.astype(jnp.float32) + _vocab_pad_bias(cfg)


def xent_loss(logits, labels):
    """logits (B,S,Vp) f32; labels (B,S) int32, -1 masked."""
    mask = labels >= 0
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1)
    return jnp.sum(nll * mask) / denom


def sinusoidal_pos(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


def _ring_fill(kv, window):
    """Scatter the last `window` positions of (B,S,N,Dh) into ring slots."""
    s = kv.shape[1]
    w = min(window, s)
    slots = (jnp.arange(s - w, s) % window).astype(jnp.int32)
    ring = jnp.zeros(kv.shape[:1] + (window,) + kv.shape[2:], kv.dtype)
    return ring.at[:, slots].set(kv[:, s - w :])


# ===========================================================================
# dense decoder (yi, internlm2, nemotron) — also the base for moe
# ===========================================================================

def _init_block(key, cfg: ModelConfig, shape=(), moe: bool = False):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.init_norm(cfg, shape),
        "ln2": L.init_norm(cfg, shape),
        "attn": L.init_attention(k1, cfg, shape),
    }
    p["mlp"] = L.init_moe(k2, cfg, shape) if moe else L.init_mlp(k2, cfg, shape)
    return p


def _block_fwd(p, x, cfg: ModelConfig, *, window=0, moe=False):
    x = x + L.attention(p["attn"], L.apply_norm(p["ln1"], x, cfg), cfg, window=window)
    h = L.apply_norm(p["ln2"], x, cfg)
    if moe:
        y, aux = L.apply_moe(p["mlp"], h, cfg)
        return x + y, aux
    return x + L.apply_mlp(p["mlp"], h, cfg), jnp.zeros((), jnp.float32)


def _block_decode(p, x, cfg, k_c, v_c, pos, *, window=0, moe=False):
    h = L.apply_norm(p["ln1"], x, cfg)
    y, k_c, v_c = L.attention_decode(p["attn"], h, cfg, k_c, v_c, pos, window=window)
    x = x + y
    h = L.apply_norm(p["ln2"], x, cfg)
    if moe:
        y, _ = L.apply_moe(p["mlp"], h, cfg)
    else:
        y = L.apply_mlp(p["mlp"], h, cfg)
    return x + y, k_c, v_c


def build_decoder(cfg: ModelConfig) -> ModelApi:
    """dense | moe | local_global dense (gemma3-style)."""
    moe = cfg.family == "moe"
    lg = cfg.attn_pattern == "local_global"
    nl = cfg.n_layers
    if lg:
        per = cfg.global_every  # 5 local + 1 global per group
        n_groups = nl // per
        n_tail = nl - n_groups * per
    nk, dh = cfg.n_kv_heads, cfg.d_head

    def init(rng):
        p = init_embeddings(rng, cfg)
        if not lg:
            p["blocks"] = _init_block(jax.random.fold_in(rng, 1), cfg, (nl,), moe)
        else:
            p["local_groups"] = _init_block(
                jax.random.fold_in(rng, 1), cfg, (n_groups, per - 1), moe
            )
            p["global_blocks"] = _init_block(
                jax.random.fold_in(rng, 2), cfg, (n_groups,), moe
            )
            if n_tail:
                p["tail"] = _init_block(
                    jax.random.fold_in(rng, 3), cfg, (n_tail,), moe
                )
        return p

    def forward(params, x):
        aux_total = jnp.zeros((), jnp.float32)
        if not lg:
            body = _maybe_remat(
                lambda xx, bp: _block_fwd(bp, xx, cfg, moe=moe), cfg
            )

            def scan_body(xx, bp):
                xx, aux = body(xx, bp)
                return xx, aux

            x, auxs = _scan(scan_body, x, params["blocks"])
            aux_total = jnp.sum(auxs)
        else:
            def local_body(xx, bp):
                xx, aux = _block_fwd(bp, xx, cfg, window=cfg.window, moe=moe)
                return xx, aux

            local_body = _maybe_remat(local_body, cfg)

            def group_body(xx, gp):
                lp, gp_blk = gp
                xx, aux1 = _scan(local_body, xx, lp)
                xx, aux2 = _block_fwd(gp_blk, xx, cfg, window=0, moe=moe)
                return xx, jnp.sum(aux1) + aux2

            x, auxs = _scan(
                group_body, x, (params["local_groups"], params["global_blocks"])
            )
            aux_total = jnp.sum(auxs)
            if n_tail:
                x, aux3 = _scan(local_body, x, params["tail"])
                aux_total = aux_total + jnp.sum(aux3)
        return x, aux_total

    def train_loss(params, batch):
        x = embed(params, batch["tokens"], cfg)
        x, aux = forward(params, x)
        logits = unembed(params, x, cfg)
        loss = xent_loss(logits, batch["labels"])
        return loss + 0.01 * aux, {"xent": loss, "aux": aux}

    def init_cache(batch_size, max_seq):
        def kv(*shape):
            return jnp.zeros(shape + (nk, dh), cfg.dtype)

        if not lg:
            return {
                "k": kv(nl, batch_size, max_seq),
                "v": kv(nl, batch_size, max_seq),
            }
        w = cfg.window
        c = {
            "lk": kv(n_groups, per - 1, batch_size, w),
            "lv": kv(n_groups, per - 1, batch_size, w),
            "gk": kv(n_groups, batch_size, max_seq),
            "gv": kv(n_groups, batch_size, max_seq),
        }
        if n_tail:
            c["tk"] = kv(n_tail, batch_size, w)
            c["tv"] = kv(n_tail, batch_size, w)
        return c

    def prefill(params, batch):
        """Full-sequence forward; emits last-position logits + a filled cache."""
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        max_seq = batch.get("max_seq", s)
        x = embed(params, tokens, cfg)
        cache = init_cache(bsz, max_seq)

        def kv_of(bp, h):
            _, k, v = L._qkv(bp["attn"], h, cfg)
            k = L.rope(k, jnp.arange(h.shape[1]), cfg.rope_theta)
            return k, v

        if not lg:
            def body(xx, bp):
                h = L.apply_norm(bp["ln1"], xx, cfg)
                k, v = kv_of(bp, h)
                xx, _ = _block_fwd(bp, xx, cfg, moe=moe)
                return xx, (k, v)

            x, (ks, vs) = _scan(body, x, params["blocks"])
            pad = max_seq - s
            if pad:
                zeros = jnp.zeros(ks.shape[:2] + (pad,) + ks.shape[3:], ks.dtype)
                ks = jnp.concatenate([ks, zeros], axis=2)
                vs = jnp.concatenate([vs, zeros], axis=2)
            cache = {"k": ks, "v": vs}
        else:
            def lbody(xx, bp):
                h = L.apply_norm(bp["ln1"], xx, cfg)
                k, v = kv_of(bp, h)
                xx, _ = _block_fwd(bp, xx, cfg, window=cfg.window, moe=moe)
                return xx, (_ring_fill(k, cfg.window), _ring_fill(v, cfg.window))

            def gbody(xx, gp):
                lp, gblk = gp
                xx, (lk, lv) = _scan(lbody, xx, lp)
                h = L.apply_norm(gblk["ln1"], xx, cfg)
                k, v = kv_of(gblk, h)
                pad = max_seq - s
                if pad:
                    z = jnp.zeros((k.shape[0], pad) + k.shape[2:], k.dtype)
                    k = jnp.concatenate([k, z], 1)
                    v = jnp.concatenate([v, z], 1)
                xx, _ = _block_fwd(gblk, xx, cfg, window=0, moe=moe)
                return xx, (lk, lv, k, v)

            x, (lk, lv, gk, gv) = _scan(
                gbody, x, (params["local_groups"], params["global_blocks"])
            )
            cache = {"lk": lk, "lv": lv, "gk": gk, "gv": gv}
            if n_tail:
                x, (tk, tv) = _scan(lbody, x, params["tail"])
                cache["tk"], cache["tv"] = tk, tv
        logits = unembed(params, x[:, -1:, :], cfg)
        return logits[:, 0], cache

    def decode_step(params, cache, tokens, pos):
        x = embed(params, tokens[:, None], cfg)
        if not lg:
            def body(xx, blk):
                bp, k_c, v_c = blk
                xx, k_c, v_c = _block_decode(bp, xx, cfg, k_c, v_c, pos, moe=moe)
                return xx, (k_c, v_c)

            x, (k2, v2) = _scan(
                body, x, (params["blocks"], cache["k"], cache["v"])
            )
            cache = {"k": k2, "v": v2}
        else:
            def lbody(xx, blk):
                bp, k_c, v_c = blk
                xx, k_c, v_c = _block_decode(
                    bp, xx, cfg, k_c, v_c, pos, window=cfg.window, moe=moe
                )
                return xx, (k_c, v_c)

            def gbody(xx, blk):
                lp, lk, lv, gblk, gk, gv = blk
                xx, (lk2, lv2) = _scan(lbody, xx, (lp, lk, lv))
                xx, gk2, gv2 = _block_decode(gblk, xx, cfg, gk, gv, pos, moe=moe)
                return xx, (lk2, lv2, gk2, gv2)

            x, (lk, lv, gk, gv) = _scan(
                gbody,
                x,
                (
                    params["local_groups"],
                    cache["lk"],
                    cache["lv"],
                    params["global_blocks"],
                    cache["gk"],
                    cache["gv"],
                ),
            )
            new_cache = {"lk": lk, "lv": lv, "gk": gk, "gv": gv}
            if n_tail:
                x, (tk, tv) = _scan(
                    lbody, x, (params["tail"], cache["tk"], cache["tv"])
                )
                new_cache["tk"], new_cache["tv"] = tk, tv
            cache = new_cache
        logits = unembed(params, x[:, 0, :], cfg)
        return logits, cache

    return ModelApi(cfg, init, train_loss, prefill, decode_step, init_cache)


# ===========================================================================
# ssm (falcon-mamba) and hybrid (zamba2)
# ===========================================================================

def build_ssm(cfg: ModelConfig) -> ModelApi:
    nl = cfg.n_layers
    init_mixer = S.init_mamba1 if cfg.ssm_kind == "mamba1" else S.init_mamba2
    fwd = S.mamba1_forward if cfg.ssm_kind == "mamba1" else S.mamba2_forward
    step = S.mamba1_step if cfg.ssm_kind == "mamba1" else S.mamba2_step
    init_state = (
        S.mamba1_init_state if cfg.ssm_kind == "mamba1" else S.mamba2_init_state
    )

    def init(rng):
        p = init_embeddings(rng, cfg)
        p["blocks"] = {
            "ln": L.init_norm(cfg, (nl,)),
            "mixer": init_mixer(jax.random.fold_in(rng, 1), cfg, (nl,)),
        }
        return p

    def block(bp, x, return_state=False):
        h = L.apply_norm(bp["ln"], x, cfg)
        if return_state:
            y, st = fwd(bp["mixer"], h, cfg, return_state=True)
            return x + y, st
        return x + fwd(bp["mixer"], h, cfg)

    block_r = _maybe_remat(lambda xx, bp: (block(bp, xx), None), cfg)

    def train_loss(params, batch):
        x = embed(params, batch["tokens"], cfg)
        x, _ = _scan(lambda xx, bp: block_r(xx, bp), x, params["blocks"])
        logits = unembed(params, x, cfg)
        loss = xent_loss(logits, batch["labels"])
        return loss, {"xent": loss}

    def init_cache(batch_size, max_seq):
        st = init_state(cfg, batch_size)
        return {
            "states": jax.tree.map(
                lambda t: jnp.zeros((nl,) + t.shape, t.dtype), st
            )
        }

    def prefill(params, batch):
        x = embed(params, batch["tokens"], cfg)

        def body(xx, bp):
            xx, st = block(bp, xx, return_state=True)
            return xx, st

        x, states = _scan(body, x, params["blocks"])
        logits = unembed(params, x[:, -1:, :], cfg)
        return logits[:, 0], {"states": states}

    def decode_step(params, cache, tokens, pos):
        x = embed(params, tokens[:, None], cfg)[:, 0]

        def body(xx, blk):
            bp, st = blk
            h = L.apply_norm(bp["ln"], xx, cfg)
            y, st = step(bp["mixer"], h, st, cfg)
            return xx + y, st

        x, states = _scan(body, x, (params["blocks"], cache["states"]))
        logits = unembed(params, x, cfg)
        return logits, {"states": states}

    return ModelApi(cfg, init, train_loss, prefill, decode_step, init_cache)


def build_hybrid(cfg: ModelConfig) -> ModelApi:
    """zamba2: mamba2 backbone + one shared attention block every N layers."""
    nl, per = cfg.n_layers, cfg.shared_attn_every
    n_groups = nl // per
    n_tail = nl - n_groups * per
    nk, dh = cfg.n_kv_heads, cfg.d_head

    def init(rng):
        p = init_embeddings(rng, cfg)
        p["groups"] = {
            "ln": L.init_norm(cfg, (n_groups, per)),
            "mixer": S.init_mamba2(jax.random.fold_in(rng, 1), cfg, (n_groups, per)),
        }
        if n_tail:
            p["tail"] = {
                "ln": L.init_norm(cfg, (n_tail,)),
                "mixer": S.init_mamba2(jax.random.fold_in(rng, 2), cfg, (n_tail,)),
            }
        p["shared_attn"] = {
            "ln": L.init_norm(cfg),
            "attn": L.init_attention(jax.random.fold_in(rng, 3), cfg),
        }
        return p

    def mamba_block(bp, x, return_state=False):
        h = L.apply_norm(bp["ln"], x, cfg)
        if return_state:
            y, st = S.mamba2_forward(bp["mixer"], h, cfg, return_state=True)
            return x + y, st
        return x + S.mamba2_forward(bp["mixer"], h, cfg)

    mamba_r = _maybe_remat(lambda xx, bp: (mamba_block(bp, xx), None), cfg)

    def train_forward(params, x):
        sp = params["shared_attn"]

        def gbody(xx, gp):
            xx, _ = _scan(lambda a, b: mamba_r(a, b), xx, gp)
            h = L.apply_norm(sp["ln"], xx, cfg)
            xx = xx + L.attention(sp["attn"], h, cfg)
            return xx, None

        x, _ = _scan(gbody, x, params["groups"])
        if n_tail:
            x, _ = _scan(lambda a, b: mamba_r(a, b), x, params["tail"])
        return x

    def train_loss(params, batch):
        x = embed(params, batch["tokens"], cfg)
        x = train_forward(params, x)
        logits = unembed(params, x, cfg)
        loss = xent_loss(logits, batch["labels"])
        return loss, {"xent": loss}

    def init_cache(batch_size, max_seq):
        st = S.mamba2_init_state(cfg, batch_size)
        cache = {
            "g_states": jax.tree.map(
                lambda t: jnp.zeros((n_groups, per) + t.shape, t.dtype), st
            ),
            "shared_k": jnp.zeros(
                (n_groups, batch_size, max_seq, nk, dh), cfg.dtype
            ),
            "shared_v": jnp.zeros(
                (n_groups, batch_size, max_seq, nk, dh), cfg.dtype
            ),
        }
        if n_tail:
            cache["t_states"] = jax.tree.map(
                lambda t: jnp.zeros((n_tail,) + t.shape, t.dtype), st
            )
        return cache

    def prefill(params, batch):
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        max_seq = batch.get("max_seq", s)
        x = embed(params, tokens, cfg)
        sp = params["shared_attn"]

        def mbody(xx, bp):
            xx, st = mamba_block(bp, xx, return_state=True)
            return xx, st

        def gbody(xx, gp):
            xx, sts = _scan(mbody, xx, gp)
            h = L.apply_norm(sp["ln"], xx, cfg)
            _, k, v = L._qkv(sp["attn"], h, cfg)
            k = L.rope(k, jnp.arange(s), cfg.rope_theta)
            pad = max_seq - s
            if pad:
                z = jnp.zeros((k.shape[0], pad) + k.shape[2:], k.dtype)
                k = jnp.concatenate([k, z], 1)
                v = jnp.concatenate([v, z], 1)
            xx = xx + L.attention(sp["attn"], h, cfg)
            return xx, (sts, k, v)

        x, (g_states, ks, vs) = _scan(gbody, x, params["groups"])
        cache = {"g_states": g_states, "shared_k": ks, "shared_v": vs}
        if n_tail:
            x, t_states = _scan(mbody, x, params["tail"])
            cache["t_states"] = t_states
        logits = unembed(params, x[:, -1:, :], cfg)
        return logits[:, 0], cache

    def decode_step(params, cache, tokens, pos):
        x = embed(params, tokens[:, None], cfg)[:, 0]
        sp = params["shared_attn"]

        def mbody(xx, blk):
            bp, st = blk
            h = L.apply_norm(bp["ln"], xx, cfg)
            y, st = S.mamba2_step(bp["mixer"], h, st, cfg)
            return xx + y, st

        def gbody(xx, blk):
            gp, gst, k_c, v_c = blk
            xx, gst = _scan(mbody, xx, (gp, gst))
            h = L.apply_norm(sp["ln"], xx[:, None, :], cfg)
            y, k_c, v_c = L.attention_decode(sp["attn"], h, cfg, k_c, v_c, pos)
            xx = xx + y[:, 0]
            return xx, (gst, k_c, v_c)

        x, (g_states, ks, vs) = _scan(
            gbody,
            x,
            (params["groups"], cache["g_states"], cache["shared_k"], cache["shared_v"]),
        )
        new_cache = {"g_states": g_states, "shared_k": ks, "shared_v": vs}
        if n_tail:
            x, t_states = _scan(
                mbody, x, (params["tail"], cache["t_states"])
            )
            new_cache["t_states"] = t_states
        logits = unembed(params, x, cfg)
        return logits, new_cache

    return ModelApi(cfg, init, train_loss, prefill, decode_step, init_cache)


# ===========================================================================
# encoder-decoder (whisper) — stubbed audio frontend (frame embeddings in)
# ===========================================================================

def build_encdec(cfg: ModelConfig) -> ModelApi:
    ne, nd = cfg.n_enc_layers, cfg.n_layers
    nk, dh = cfg.n_kv_heads, cfg.d_head

    def init(rng):
        p = init_embeddings(rng, cfg)
        p["enc_blocks"] = _init_block(jax.random.fold_in(rng, 1), cfg, (ne,))
        k = jax.random.fold_in(rng, 2)
        p["dec_blocks"] = {
            "ln1": L.init_norm(cfg, (nd,)),
            "ln_x": L.init_norm(cfg, (nd,)),
            "ln2": L.init_norm(cfg, (nd,)),
            "self": L.init_attention(jax.random.fold_in(k, 0), cfg, (nd,)),
            "cross": L.init_attention(jax.random.fold_in(k, 1), cfg, (nd,)),
            "mlp": L.init_mlp(jax.random.fold_in(k, 2), cfg, (nd,)),
        }
        p["enc_norm"] = L.init_norm(cfg)
        return p

    def encode(params, frames):
        x = frames.astype(cfg.dtype)
        x = x + jnp.asarray(sinusoidal_pos(x.shape[1], cfg.d_model), cfg.dtype)

        def body(xx, bp):
            h = L.apply_norm(bp["ln1"], xx, cfg)
            xx = xx + L.attention(bp["attn"], h, cfg, causal=False)
            h = L.apply_norm(bp["ln2"], xx, cfg)
            return xx + L.apply_mlp(bp["mlp"], h, cfg), None

        body = _maybe_remat(body, cfg)
        x, _ = _scan(body, x, params["enc_blocks"])
        return L.apply_norm(params["enc_norm"], x, cfg)

    def dec_block(bp, x, enc_out, cfg=cfg):
        h = L.apply_norm(bp["ln1"], x, cfg)
        x = x + L.attention(bp["self"], h, cfg)
        h = L.apply_norm(bp["ln_x"], x, cfg)
        x = x + L.attention(bp["cross"], h, cfg, kv_input=enc_out, causal=False)
        h = L.apply_norm(bp["ln2"], x, cfg)
        return x + L.apply_mlp(bp["mlp"], h, cfg)

    def decode_full(params, tokens, enc_out):
        x = embed(params, tokens, cfg)
        body = _maybe_remat(
            lambda xx, bp: (dec_block(bp, xx, enc_out), None), cfg
        )
        x, _ = _scan(body, x, params["dec_blocks"])
        return x

    def train_loss(params, batch):
        enc_out = encode(params, batch["enc_embed"])
        x = decode_full(params, batch["tokens"], enc_out)
        logits = unembed(params, x, cfg)
        loss = xent_loss(logits, batch["labels"])
        return loss, {"xent": loss}

    def init_cache(batch_size, max_seq, enc_seq=None):
        se = enc_seq or cfg.enc_seq
        kv = lambda *sh: jnp.zeros(sh + (nk, dh), cfg.dtype)
        return {
            "self_k": kv(nd, batch_size, max_seq),
            "self_v": kv(nd, batch_size, max_seq),
            "cross_k": kv(nd, batch_size, se),
            "cross_v": kv(nd, batch_size, se),
        }

    def prefill(params, batch):
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        max_seq = batch.get("max_seq", s)
        enc_out = encode(params, batch["enc_embed"])
        x = embed(params, tokens, cfg)

        def body(xx, bp):
            h = L.apply_norm(bp["ln1"], xx, cfg)
            _, k, v = L._qkv(bp["self"], h, cfg)
            k = L.rope(k, jnp.arange(s), cfg.rope_theta)
            ck, cv = L.cross_kv(bp["cross"], enc_out, cfg)
            pad = max_seq - s
            if pad:
                z = jnp.zeros((k.shape[0], pad) + k.shape[2:], k.dtype)
                k = jnp.concatenate([k, z], 1)
                v = jnp.concatenate([v, z], 1)
            xx = dec_block(bp, xx, enc_out)
            return xx, (k, v, ck, cv)

        x, (sk, sv, ck, cv) = _scan(body, x, params["dec_blocks"])
        cache = {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}
        logits = unembed(params, x[:, -1:, :], cfg)
        return logits[:, 0], cache

    def decode_step(params, cache, tokens, pos):
        x = embed(params, tokens[:, None], cfg)

        def body(xx, blk):
            bp, k_c, v_c, ck, cv = blk
            h = L.apply_norm(bp["ln1"], xx, cfg)
            y, k_c, v_c = L.attention_decode(bp["self"], h, cfg, k_c, v_c, pos)
            xx = xx + y
            h = L.apply_norm(bp["ln_x"], xx, cfg)
            xx = xx + L.attention_decode_cross(bp["cross"], h, cfg, ck, cv)
            h = L.apply_norm(bp["ln2"], xx, cfg)
            xx = xx + L.apply_mlp(bp["mlp"], h, cfg)
            return xx, (k_c, v_c)

        x, (sk, sv) = _scan(
            body,
            x,
            (
                params["dec_blocks"],
                cache["self_k"],
                cache["self_v"],
                cache["cross_k"],
                cache["cross_v"],
            ),
        )
        cache = dict(cache, self_k=sk, self_v=sv)
        logits = unembed(params, x[:, 0, :], cfg)
        return logits, cache

    return ModelApi(cfg, init, train_loss, prefill, decode_step, init_cache)


# ===========================================================================
# vlm (llama-3.2-vision): every Nth layer cross-attends to patch embeddings
# ===========================================================================

def build_vlm(cfg: ModelConfig) -> ModelApi:
    per = cfg.cross_attn_every
    n_groups = cfg.n_layers // per
    n_self = per - 1
    nk, dh = cfg.n_kv_heads, cfg.d_head

    def init(rng):
        p = init_embeddings(rng, cfg)
        p["self_groups"] = _init_block(
            jax.random.fold_in(rng, 1), cfg, (n_groups, n_self)
        )
        k = jax.random.fold_in(rng, 2)
        p["cross_blocks"] = {
            "ln1": L.init_norm(cfg, (n_groups,)),
            "ln2": L.init_norm(cfg, (n_groups,)),
            "attn": L.init_attention(jax.random.fold_in(k, 0), cfg, (n_groups,)),
            "mlp": L.init_mlp(jax.random.fold_in(k, 1), cfg, (n_groups,)),
            "gate": jnp.zeros((n_groups,), cfg.param_dtype),  # zero-init gate
        }
        return p

    def cross_block(bp, x, img, cfg=cfg):
        h = L.apply_norm(bp["ln1"], x, cfg)
        g = jnp.tanh(bp["gate"]).astype(cfg.dtype)
        x = x + g * L.attention(bp["attn"], h, cfg, kv_input=img, causal=False)
        h = L.apply_norm(bp["ln2"], x, cfg)
        return x + L.apply_mlp(bp["mlp"], h, cfg)

    def forward(params, x, img):
        sbody = _maybe_remat(
            lambda xx, bp: (_block_fwd(bp, xx, cfg)[0], None), cfg
        )

        def gbody(xx, gp):
            sp, cp = gp
            xx, _ = _scan(sbody, xx, sp)
            xx = cross_block(cp, xx, img)
            return xx, None

        x, _ = _scan(
            gbody, x, (params["self_groups"], params["cross_blocks"])
        )
        return x

    def train_loss(params, batch):
        img = batch["img_embed"].astype(cfg.dtype)
        x = embed(params, batch["tokens"], cfg)
        x = forward(params, x, img)
        logits = unembed(params, x, cfg)
        loss = xent_loss(logits, batch["labels"])
        return loss, {"xent": loss}

    def init_cache(batch_size, max_seq, n_img=None):
        ni = n_img or cfg.n_img_tokens
        kv = lambda *sh: jnp.zeros(sh + (nk, dh), cfg.dtype)
        return {
            "self_k": kv(n_groups, n_self, batch_size, max_seq),
            "self_v": kv(n_groups, n_self, batch_size, max_seq),
            "cross_k": kv(n_groups, batch_size, ni),
            "cross_v": kv(n_groups, batch_size, ni),
        }

    def prefill(params, batch):
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        max_seq = batch.get("max_seq", s)
        img = batch["img_embed"].astype(cfg.dtype)
        x = embed(params, tokens, cfg)

        def sbody(xx, bp):
            h = L.apply_norm(bp["ln1"], xx, cfg)
            _, k, v = L._qkv(bp["attn"], h, cfg)
            k = L.rope(k, jnp.arange(s), cfg.rope_theta)
            pad = max_seq - s
            if pad:
                z = jnp.zeros((k.shape[0], pad) + k.shape[2:], k.dtype)
                k = jnp.concatenate([k, z], 1)
                v = jnp.concatenate([v, z], 1)
            xx, _ = _block_fwd(bp, xx, cfg)
            return xx, (k, v)

        def gbody(xx, gp):
            sp, cp = gp
            xx, (k, v) = _scan(sbody, xx, sp)
            ck, cv = L.cross_kv(cp["attn"], img, cfg)
            xx = cross_block(cp, xx, img)
            return xx, (k, v, ck, cv)

        x, (sk, sv, ck, cv) = _scan(
            gbody, x, (params["self_groups"], params["cross_blocks"])
        )
        cache = {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}
        logits = unembed(params, x[:, -1:, :], cfg)
        return logits[:, 0], cache

    def decode_step(params, cache, tokens, pos):
        x = embed(params, tokens[:, None], cfg)

        def sbody(xx, blk):
            bp, k_c, v_c = blk
            xx, k_c, v_c = _block_decode(bp, xx, cfg, k_c, v_c, pos)
            return xx, (k_c, v_c)

        def gbody(xx, blk):
            sp, sk, sv, cp, ck, cv = blk
            xx, (sk2, sv2) = _scan(sbody, xx, (sp, sk, sv))
            h = L.apply_norm(cp["ln1"], xx, cfg)
            g = jnp.tanh(cp["gate"]).astype(cfg.dtype)
            xx = xx + g * L.attention_decode_cross(cp["attn"], h, cfg, ck, cv)
            h = L.apply_norm(cp["ln2"], xx, cfg)
            xx = xx + L.apply_mlp(cp["mlp"], h, cfg)
            return xx, (sk2, sv2)

        x, (sk, sv) = _scan(
            gbody,
            x,
            (
                params["self_groups"],
                cache["self_k"],
                cache["self_v"],
                params["cross_blocks"],
                cache["cross_k"],
                cache["cross_v"],
            ),
        )
        cache = dict(cache, self_k=sk, self_v=sv)
        logits = unembed(params, x[:, 0, :], cfg)
        return logits, cache

    return ModelApi(cfg, init, train_loss, prefill, decode_step, init_cache)


# ===========================================================================
# dispatch
# ===========================================================================

def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "moe"):
        return build_decoder(cfg)
    if cfg.family == "ssm":
        return build_ssm(cfg)
    if cfg.family == "hybrid":
        return build_hybrid(cfg)
    if cfg.family == "encdec":
        return build_encdec(cfg)
    if cfg.family == "vlm":
        return build_vlm(cfg)
    raise ValueError(f"unknown family {cfg.family}")
