"""Unified model configuration for the assigned architecture pool.

One composable ``ModelConfig`` covers the six families (dense / moe / ssm /
hybrid / encdec / vlm); per-arch configs live in ``repro.configs.<id>`` and
are exact transcriptions of the assignment table.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "swiglu"  # swiglu | squared_relu | gelu
    tie_embeddings: bool = False

    # attention pattern
    attn_pattern: str = "full"  # full | local_global
    window: int = 1024
    global_every: int = 6  # one global layer per this many (local_global)
    rope_theta: float = 10000.0
    use_layernorm: bool = False  # RMSNorm default; LN for whisper

    # mixture of experts
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # state-space (mamba)
    ssm_kind: str = ""  # mamba1 | mamba2
    d_state: int = 16
    expand: int = 2
    conv_dim: int = 4
    ssm_head_dim: int = 64  # mamba2
    ssm_chunk: int = 256  # mamba2 SSD chunk length
    dt_rank: int = 0  # mamba1 (0 -> d_model // 16)
    scan_chunk: int = 512  # mamba1 memory-chunked scan

    # hybrid (zamba2): one shared attention block applied every N layers
    shared_attn_every: int = 0

    # encoder-decoder (whisper): encoder depth + stub frontend frames
    n_enc_layers: int = 0
    enc_seq: int = 0

    # vlm: every Nth layer cross-attends to stubbed patch embeddings
    cross_attn_every: int = 0
    n_img_tokens: int = 0

    # numerics / memory policy
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "none"  # none | dots | full
    vocab_pad_to: int = 256

    # ---------------- derived -----------------
    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def dt_rank_eff(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab + m - 1) // m) * m

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for rooflines."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head + self.n_heads * self.d_head * d
        if self.act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe":
            e_mlp = self.n_experts * 3 * d * self.d_expert
            e_mlp += self.n_shared_experts * 3 * d * self.d_expert
            e_mlp += d * self.n_experts  # router
            per_layer = attn + e_mlp
        elif self.family == "ssm":
            di, ds = self.d_inner, self.d_state
            per_layer = (
                d * 2 * di
                + di * self.conv_dim
                + di * (self.dt_rank_eff + 2 * ds)
                + self.dt_rank_eff * di
                + di * ds
                + di
                + di * d
            )
        elif self.family == "hybrid":
            di = self.d_inner
            h = self.n_ssm_heads
            ds = self.d_state
            per_layer = (
                d * (2 * di + 2 * ds + h) + (di + 2 * ds) * self.conv_dim
                + h + h + di * d
            )
        else:
            per_layer = attn + mlp
        total = emb + self.n_layers * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            total += attn + 2 * d  # one shared attention block
        if self.family == "encdec":
            total += self.n_enc_layers * (attn + mlp)
            total += self.n_layers * attn  # cross-attention blocks
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * attn  # cross blocks replace none, add x-attn
        return int(total)

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.n_params
        d = self.d_model
        dense_experts = self.top_k + self.n_shared_experts
        act_mlp = dense_experts * 3 * d * self.d_expert + d * self.n_experts
        attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head + self.n_heads * self.d_head * d
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return int(emb + self.n_layers * (attn + act_mlp))


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input shape x step kind) cell of the assignment."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# long_500k only runs for O(1)-state / windowed archs (DESIGN.md §4 skips)
LONG_CTX_ARCHS = {"falcon-mamba-7b", "zamba2-7b"}


def cells_for(arch_name: str):
    out = []
    for cell in SHAPES.values():
        if cell.name == "long_500k" and arch_name not in LONG_CTX_ARCHS:
            continue
        out.append(cell)
    return out
