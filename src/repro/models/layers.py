"""Shared transformer layer library (pure-JAX pytree params, explicit einsums).

Conventions:
  x: (B, S, D) activations in cfg.dtype; params in cfg.param_dtype (cast at use)
  attention caches: k/v (B, S_cache, N_kv, Dh)
  all init fns take an explicit PRNG key and return nested dict pytrees
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

NEG_INF = -1e9  # additive mask value (bf16-safe)

# Activation-sharding rules, set by the launch layer (sharding.activation_rules)
# before lowering and cleared after. Keys: attn_q / attn_kv / moe_buf /
# ssm_scan. Empty dict -> no constraints (the paper-faithful baseline plan).
ACT_RULES: Dict[str, object] = {}


def constrain(x, key: str):
    spec = ACT_RULES.get(key)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def cast(x, cfg: ModelConfig):
    return x.astype(cfg.dtype)


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, shape=()):
    d = cfg.d_model
    p = {"scale": jnp.ones(shape + (d,), cfg.param_dtype)}
    if cfg.use_layernorm:
        p["bias"] = jnp.zeros(shape + (d,), cfg.param_dtype)
    return p


def apply_norm(p, x, cfg: ModelConfig, eps=1e-6):
    xf = x.astype(jnp.float32)
    if cfg.use_layernorm:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, N, Dh), positions: (B, S) or (S,) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, causal / sliding-window / cross)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, shape=()):
    d, nh, nk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    sc = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(nh * dh)
    return {
        "wq": _normal(ks[0], shape + (d, nh * dh), sc, cfg.param_dtype),
        "wk": _normal(ks[1], shape + (d, nk * dh), sc, cfg.param_dtype),
        "wv": _normal(ks[2], shape + (d, nk * dh), sc, cfg.param_dtype),
        "wo": _normal(ks[3], shape + (nh * dh, d), so, cfg.param_dtype),
    }


def _qkv(p, x, cfg: ModelConfig, kv_input=None):
    b, s, _ = x.shape
    nh, nk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    kv_in = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,dh->bsh", x, cast(p["wq"], cfg)).reshape(b, s, nh, dh)
    k = jnp.einsum("bsd,dh->bsh", kv_in, cast(p["wk"], cfg)).reshape(
        b, kv_in.shape[1], nk, dh
    )
    v = jnp.einsum("bsd,dh->bsh", kv_in, cast(p["wv"], cfg)).reshape(
        b, kv_in.shape[1], nk, dh
    )
    q = constrain(q, "attn_q")
    k = constrain(k, "attn_kv")
    v = constrain(v, "attn_kv")
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q: (B,Sq,Nh,Dh), k/v: (B,Sk,Nkv,Dh), mask: (B|1, Sq, Sk) bool or None."""
    b, sq, nh, dh = q.shape
    nk = k.shape[2]
    g = nh // nk
    qg = q.reshape(b, sq, nk, g, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits = logits / np.sqrt(dh)
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v).reshape(b, sq, nh * dh)
    return out


def causal_mask(sq: int, sk: int, offset: int = 0, window: int = 0):
    """bool (1, sq, sk): query i attends keys j with j <= i+offset
    and (window == 0 or j > i+offset-window)."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window:
        m = m & (kj > qi - window)
    return m[None]


def attention(p, x, cfg: ModelConfig, *, window: int = 0, positions=None,
              kv_input=None, causal: bool = True):
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, kv_input=kv_input)
    if kv_input is None:  # self-attention: rope over shared positions
        pos = positions if positions is not None else jnp.arange(s)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        mask = causal_mask(s, s, 0, window) if causal else None
    else:
        mask = None  # cross-attention: all encoder/image tokens visible
    out = _sdpa(q, k, v, mask, cfg)
    return jnp.einsum("bsh,hd->bsd", out, cast(p["wo"], cfg))


def attention_decode(p, x, cfg: ModelConfig, cache_k, cache_v, pos, *,
                     window: int = 0):
    """One-token decode with cache update.

    x: (B, 1, D); cache_k/v: (B, C, Nkv, Dh); pos: int32 scalar — absolute
    position of the new token. For windowed layers the cache is a ring buffer
    of C == window slots (slot = pos % C); for full layers C == max_seq.
    """
    b = x.shape[0]
    nk, dh = cfg.n_kv_heads, cfg.d_head
    c = cache_k.shape[1]
    q, k, v = _qkv(p, x, cfg)
    q = rope(q, jnp.full((1,), pos), cfg.rope_theta)
    k = rope(k, jnp.full((1,), pos), cfg.rope_theta)
    slot = jnp.where(window, pos % jnp.maximum(c, 1), pos)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    kj = jnp.arange(c)[None, :]
    if window:
        valid = (kj <= pos % c) | (pos >= c)  # ring buffer fill state
        # ring semantics: every resident slot is within the window by
        # construction once pos >= c; before that only slots <= pos are live
        mask = valid[:, None, :]
    else:
        mask = (kj <= pos)[:, None, :]
    out = _sdpa(q, cache_k, cache_v, mask, cfg)
    y = jnp.einsum("bsh,hd->bsd", out, cast(p["wo"], cfg))
    return y, cache_k, cache_v


def attention_decode_cross(p, x, cfg: ModelConfig, cross_k, cross_v):
    """Decode-time cross attention against precomputed encoder K/V."""
    q, _, _ = _qkv(p, x, cfg)
    out = _sdpa(q, cross_k, cross_v, None, cfg)
    return jnp.einsum("bsh,hd->bsd", out, cast(p["wo"], cfg))


def cross_kv(p, enc_out, cfg: ModelConfig):
    b, se, _ = enc_out.shape
    nk, dh = cfg.n_kv_heads, cfg.d_head
    k = jnp.einsum("bsd,dh->bsh", enc_out, cast(p["wk"], cfg)).reshape(b, se, nk, dh)
    v = jnp.einsum("bsd,dh->bsh", enc_out, cast(p["wv"], cfg)).reshape(b, se, nk, dh)
    return k, v


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, shape=(), d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    sc, so = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {"wo": _normal(ks[2], shape + (f, d), so, cfg.param_dtype)}
    if cfg.act == "swiglu":
        p["wg"] = _normal(ks[0], shape + (d, f), sc, cfg.param_dtype)
        p["wi"] = _normal(ks[1], shape + (d, f), sc, cfg.param_dtype)
    else:
        p["wi"] = _normal(ks[1], shape + (d, f), sc, cfg.param_dtype)
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, cast(p["wg"], cfg))
        h = jnp.einsum("bsd,df->bsf", x, cast(p["wi"], cfg))
        a = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("bsd,df->bsf", x, cast(p["wi"], cfg))
        if cfg.act == "squared_relu":
            a = jnp.square(jax.nn.relu(h))
        else:
            a = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", a, cast(p["wo"], cfg))


# ---------------------------------------------------------------------------
# mixture of experts (GShard-style capacity dispatch; EP/TP shardable)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, shape=()):
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    sc, so = 1.0 / np.sqrt(d), 1.0 / np.sqrt(fe)
    p = {
        "router": _normal(ks[0], shape + (d, e), sc, cfg.param_dtype),
        "wg": _normal(ks[1], shape + (e, d, fe), sc, cfg.param_dtype),
        "wi": _normal(ks[2], shape + (e, d, fe), sc, cfg.param_dtype),
        "wo": _normal(ks[3], shape + (e, fe, d), so, cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        sh_cfg = cfg
        p["shared"] = init_mlp(
            ks[4], sh_cfg, shape, d_ff=cfg.d_expert * cfg.n_shared_experts
        )
    return p


def moe_capacity(cfg: ModelConfig, tokens: int) -> int:
    cap = int(np.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, ((cap + 7) // 8) * 8)


def apply_moe(p, x, cfg: ModelConfig):
    """Top-k routed experts with static capacity (overflow tokens dropped —
    standard GShard semantics; aux load-balance loss returned)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = moe_capacity(cfg, t)
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, cast(p["router"], cfg)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, k)  # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.int32)  # (t, k, e)
    flat_oh = onehot.reshape(t * k, e)
    pos_in_e = jnp.cumsum(flat_oh, axis=0) - flat_oh  # exclusive
    pos = jnp.sum(pos_in_e * flat_oh, axis=-1).reshape(t, k)
    fits = pos < cap

    # dispatch: scatter tokens into (e, cap, d)
    buf = jnp.zeros((e, cap, d), dtype=x.dtype)
    ei = jnp.where(fits, eidx, e)  # drop overflow
    pi = jnp.where(fits, pos, 0)
    tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k))
    buf = buf.at[ei, pi].set(xt[tok_idx], mode="drop")
    buf = constrain(buf, "moe_buf")

    # expert FFN (einsum over stacked experts -> MXU-friendly, EP-shardable)
    if cfg.act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, cast(p["wg"], cfg))
        h = jnp.einsum("ecd,edf->ecf", buf, cast(p["wi"], cfg))
        a = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("ecd,edf->ecf", buf, cast(p["wi"], cfg))
        a = jnp.square(jax.nn.relu(h)) if cfg.act == "squared_relu" else jax.nn.gelu(h)
    out_buf = jnp.einsum("ecf,efd->ecd", a, cast(p["wo"], cfg))

    # combine: gather back and weight
    gathered = out_buf[ei, pi]  # (t, k, d); overflow reads expert e -> OOB
    gathered = jnp.where(fits[..., None], gathered, 0.0)
    yt = jnp.sum(gathered * gate_vals[..., None].astype(x.dtype), axis=1)

    if cfg.n_shared_experts:
        yt = yt + apply_mlp(p["shared"], xt[None], cfg)[0]

    # load-balance aux loss (Switch): e * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32), axis=0)
    ) / t
    frac = jnp.sum(jax.nn.one_hot(eidx, e, dtype=jnp.float32), axis=(0, 1)) / (t * k)
    aux = e * jnp.sum(frac * me)
    return yt.reshape(b, s, d), aux
