"""Model substrate: configs, layers, SSM blocks, family assemblies."""
from .config import LONG_CTX_ARCHS, SHAPES, ModelConfig, ShapeCell, cells_for
from .model import ModelApi, build_model

__all__ = [
    "LONG_CTX_ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeCell",
    "cells_for",
    "ModelApi",
    "build_model",
]
