"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD, chunked).

TPU adaptation notes (DESIGN.md §4): Mamba-1 uses a memory-chunked hybrid
scan — outer ``lax.scan`` over sequence chunks carrying the SSM state, inner
``associative_scan`` within each chunk, so the (B, S, d_inner, d_state)
tensor never materializes. Mamba-2 uses the SSD block-matmul formulation
(chunked attention-like intra-block einsums + inter-chunk state recurrence),
which maps onto the MXU instead of the VPU-bound elementwise scan.

Both are validated in tests/test_ssm.py against a naive per-step recurrence.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _normal, cast, constrain

# When True, the inter-chunk state recurrences use (unrolled) associative
# scans instead of a sequential lax.scan: log-depth on real hardware and —
# crucial for the dry-run probes — every trip is visible to XLA cost
# analysis (a while-loop body is counted once). Slightly more memory.
SCAN_ASSOC = False


def _assoc_linear(decay, inject, axis: int):
    """h_i = h_{i-1} * decay_i + inject_i via associative scan along ``axis``.

    Returns (h_after, h_before): inclusive and exclusive (shift-right) scans.
    decay broadcasts against inject over trailing dims.
    """

    def comb(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s1 * d2 + s2

    d_after, h_after = jax.lax.associative_scan(comb, (decay, inject), axis=axis)
    zero = jnp.zeros_like(jax.lax.slice_in_dim(inject, 0, 1, axis=axis))
    h_before = jnp.concatenate(
        [zero, jax.lax.slice_in_dim(h_after, 0, inject.shape[axis] - 1, axis=axis)],
        axis=axis,
    )
    return h_after, h_before


# ---------------------------------------------------------------------------
# causal depthwise conv1d (shared by both mamba variants)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, C), w: (K, C), b: (C,) — depthwise causal convolution."""
    k = w.shape[0]
    c = x.shape[-1]
    out = jax.lax.conv_general_dilated(
        x,
        w[:, None, :].astype(x.dtype),  # (K, 1, C) with feature groups = C
        window_strides=(1,),
        padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    return out + b.astype(x.dtype)


def conv_step(conv_state: jax.Array, x_t: jax.Array, w: jax.Array, b: jax.Array):
    """Decode-time conv: conv_state (B, K-1, C) FIFO, x_t (B, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w.astype(x_t.dtype)) + b.astype(x_t.dtype)
    return window[:, 1:], y


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba)
# ---------------------------------------------------------------------------

def init_mamba1(key, cfg: ModelConfig, shape=()):
    d, di, ds, kc = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.conv_dim
    dtr = cfg.dt_rank_eff
    ks = jax.random.split(key, 6)
    pd = cfg.param_dtype
    a_init = jnp.broadcast_to(
        jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32)), shape + (di, ds)
    ).astype(pd)
    return {
        "in_proj": _normal(ks[0], shape + (d, 2 * di), 1 / np.sqrt(d), pd),
        "conv_w": _normal(ks[1], shape + (kc, di), 1 / np.sqrt(kc), pd),
        "conv_b": jnp.zeros(shape + (di,), pd),
        "x_proj": _normal(ks[2], shape + (di, dtr + 2 * ds), 1 / np.sqrt(di), pd),
        "dt_proj": _normal(ks[3], shape + (dtr, di), 1 / np.sqrt(dtr), pd),
        "dt_bias": jnp.full(shape + (di,), -4.6, pd),  # softplus^-1(0.01)
        "A_log": a_init,
        "D": jnp.ones(shape + (di,), pd),
        "out_proj": _normal(ks[4], shape + (di, d), 1 / np.sqrt(di), pd),
    }


def _mamba1_inner(cfg, x_conv, dt, b_t, c_t, a, h0):
    """Linear recurrence h_t = exp(dt A) h_{t-1} + dt B x over one chunk.

    x_conv/dt: (B, C, Di); b_t/c_t: (B, C, Ds); a: (Di, Ds); h0: (B, Di, Ds).
    """
    da = constrain(jnp.exp(dt[..., None] * a), "ssm_scan")  # (B, C, Di, Ds)
    dbx = constrain((dt * x_conv)[..., None] * b_t[:, :, None, :], "ssm_scan")

    def comb(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(comb, (da, dbx), axis=1)
    h = b_cum + a_cum * h0[:, None]  # (B, C, Di, Ds)
    y = jnp.sum(h * c_t[:, :, None, :], axis=-1)  # (B, C, Di)
    return y, h[:, -1]


def mamba1_forward(p, x, cfg: ModelConfig, return_state: bool = False):
    """Full-sequence Mamba-1 mixer. x: (B, S, D) -> (B, S, D).

    With ``return_state`` also returns the decode state after position S-1
    (prefill -> decode handoff)."""
    b, s, d = x.shape
    di, ds, dtr = cfg.d_inner, cfg.d_state, cfg.dt_rank_eff
    xz = jnp.einsum("bsd,de->bse", x, cast(p["in_proj"], cfg))
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv = jax.nn.silu(causal_conv1d(x_in, p["conv_w"], p["conv_b"]))

    dbc = jnp.einsum("bsi,ie->bse", x_conv, cast(p["x_proj"], cfg))
    dt_lr = dbc[..., :dtr]
    b_t = dbc[..., dtr : dtr + ds].astype(jnp.float32)
    c_t = dbc[..., dtr + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_lr, cast(p["dt_proj"], cfg)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xc32 = x_conv.astype(jnp.float32)

    chunk = min(cfg.scan_chunk, s)
    if s % chunk:
        chunk = s  # fall back to single chunk for odd smoke shapes
    nc = s // chunk

    if SCAN_ASSOC:
        # two-level associative form: per-chunk cumulatives in parallel,
        # then an associative scan over chunk summaries (DESIGN.md §4)
        da = constrain(
            jnp.exp(dt[..., None] * a).reshape(b, nc, chunk, di, ds), "ssm_scan5"
        )
        dbx = constrain(
            ((dt * xc32)[..., None] * b_t[:, :, None, :]).reshape(
                b, nc, chunk, di, ds
            ),
            "ssm_scan5",
        )
        a_cum, b_cum = jax.lax.associative_scan(
            lambda e1, e2: (e1[0] * e2[0], e2[0] * e1[1] + e2[1]),
            (da, dbx),
            axis=2,
        )
        h_aft, h_bef = _assoc_linear(a_cum[:, :, -1], b_cum[:, :, -1], axis=1)
        h = b_cum + a_cum * h_bef[:, :, None]
        y = jnp.sum(
            h * c_t.reshape(b, nc, chunk, 1, ds), axis=-1
        ).reshape(b, s, di)
        h_last = h_aft[:, -1]
    else:
        def outer(h0, inputs):
            xc_c, dt_c, b_c, c_c = inputs
            y, h1 = _mamba1_inner(cfg, xc_c, dt_c, b_c, c_c, a, h0)
            return h1, y

        resh = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
        h0 = jnp.zeros((b, di, ds), jnp.float32)
        h_last, ys = jax.lax.scan(
            outer, h0, (resh(xc32), resh(dt), resh(b_t), resh(c_t))
        )
        y = ys.swapaxes(0, 1).reshape(b, s, di)
    y = y + p["D"].astype(jnp.float32) * xc32
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, cast(p["out_proj"], cfg))
    if return_state:
        kc = cfg.conv_dim
        conv_state = x_in.astype(jnp.float32)[:, s - kc + 1 :, :]
        return out, {"conv": conv_state, "ssm": h_last}
    return out


def mamba1_init_state(cfg: ModelConfig, batch: int):
    return {
        "conv": jnp.zeros((batch, cfg.conv_dim - 1, cfg.d_inner), jnp.float32),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    }


def mamba1_step(p, x_t, state, cfg: ModelConfig):
    """One decode step. x_t: (B, D) -> (B, D); state updated in place."""
    di, ds, dtr = cfg.d_inner, cfg.d_state, cfg.dt_rank_eff
    xz = jnp.einsum("bd,de->be", x_t, cast(p["in_proj"], cfg))
    x_in, z = jnp.split(xz, 2, axis=-1)
    conv_state, xc = conv_step(
        state["conv"], x_in.astype(jnp.float32), p["conv_w"], p["conv_b"]
    )
    xc = jax.nn.silu(xc)
    dbc = jnp.einsum("bi,ie->be", xc.astype(x_t.dtype), cast(p["x_proj"], cfg))
    dt_lr, b_t, c_t = (
        dbc[..., :dtr],
        dbc[..., dtr : dtr + ds].astype(jnp.float32),
        dbc[..., dtr + ds :].astype(jnp.float32),
    )
    dt = jax.nn.softplus(
        jnp.einsum("br,ri->bi", dt_lr, cast(p["dt_proj"], cfg)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt[:, :, None] * a)  # (B, Di, Ds)
    h = da * state["ssm"] + (dt * xc)[:, :, None] * b_t[:, None, :]
    y = jnp.sum(h * c_t[:, None, :], axis=-1) + p["D"].astype(jnp.float32) * xc
    y = y.astype(x_t.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", y, cast(p["out_proj"], cfg))
    return out, {"conv": conv_state, "ssm": h}


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2 backbone)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig, shape=()):
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.d_state
    h = cfg.n_ssm_heads
    kc = cfg.conv_dim
    conv_ch = di + 2 * ds
    ks = jax.random.split(key, 4)
    pd = cfg.param_dtype
    return {
        "in_proj": _normal(
            ks[0], shape + (d, 2 * di + 2 * ds + h), 1 / np.sqrt(d), pd
        ),
        "conv_w": _normal(ks[1], shape + (kc, conv_ch), 1 / np.sqrt(kc), pd),
        "conv_b": jnp.zeros(shape + (conv_ch,), pd),
        "dt_bias": jnp.zeros(shape + (h,), pd),
        "A_log": jnp.zeros(shape + (h,), pd),
        "D": jnp.ones(shape + (h,), pd),
        "norm_scale": jnp.ones(shape + (di,), pd),
        "out_proj": _normal(ks[2], shape + (di, d), 1 / np.sqrt(di), pd),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., C) -> (..., C, C) with out[i, j] = sum_{k=j+1..i} x_k (i >= j)."""
    c = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = np.tril(np.ones((c, c), bool), 0)
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(x, dt, a, b_t, c_t, chunk: int):
    """SSD (Mamba-2) block-matmul scan.

    x: (B,S,H,P), dt: (B,S,H) (post-softplus), a: (H,) negative,
    b_t/c_t: (B,S,N). Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    bsz, s, h, p = x.shape
    n = b_t.shape[-1]
    if s % chunk:
        chunk = s
    nc = s // chunk
    xdt = (x * dt[..., None]).astype(jnp.float32)
    da = (dt * a).astype(jnp.float32)  # (B,S,H)

    xc = xdt.reshape(bsz, nc, chunk, h, p)
    dac = da.reshape(bsz, nc, chunk, h)
    bc = b_t.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cc = c_t.reshape(bsz, nc, chunk, n).astype(jnp.float32)

    dac_cs = jnp.cumsum(dac, axis=2)  # (B,nc,C,H)
    # intra-chunk (attention-like, MXU-bound)
    l_mat = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))  # (B,nc,H,C,Z)
    scores = jnp.einsum("bncd,bnzd->bncz", cc, bc)
    y_diag = jnp.einsum("bncz,bnhcz,bnzhp->bnchp", scores, l_mat, xc)

    # chunk-final states
    decay_to_end = jnp.exp(dac_cs[:, :, -1:, :] - dac_cs)  # (B,nc,C,H)
    s_chunk = jnp.einsum("bnzd,bnzh,bnzhp->bnhdp", bc, decay_to_end, xc)
    chunk_decay = jnp.exp(dac_cs[:, :, -1, :])  # (B,nc,H)

    if SCAN_ASSOC:
        h_after, h_before = _assoc_linear(
            chunk_decay[..., None, None], s_chunk, axis=1
        )
        h_last = h_after[:, -1]
    else:
        def body(h_in, inp):
            cd, s_c = inp  # (B,H), (B,H,N,P)
            h_bef = h_in
            h_out = h_in * cd[..., None, None] + s_c
            return h_out, h_bef

        h_last, h_before = jax.lax.scan(
            body,
            jnp.zeros((bsz, h, n, p), jnp.float32),
            (chunk_decay.swapaxes(0, 1), s_chunk.swapaxes(0, 1)),
        )
        h_before = h_before.swapaxes(0, 1)  # (B,nc,H,N,P)

    decay_from_start = jnp.exp(dac_cs)  # (B,nc,C,H)
    y_off = jnp.einsum("bncd,bnch,bnhdp->bnchp", cc, decay_from_start, h_before)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, h_last


def mamba2_forward(p, x, cfg: ModelConfig, return_state: bool = False):
    """Full-sequence Mamba-2 mixer. x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    di, ds, h = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    pdim = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, cast(p["in_proj"], cfg))
    z = proj[..., :di]
    xbc_pre = proj[..., di : di + di + 2 * ds]
    dt_raw = proj[..., di + di + 2 * ds :]
    xbc = jax.nn.silu(causal_conv1d(xbc_pre, p["conv_w"], p["conv_b"]))
    x_in = xbc[..., :di].reshape(b, s, h, pdim)
    b_t = xbc[..., di : di + ds]
    c_t = xbc[..., di + ds :]
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_last = ssd_chunked(x_in, dt, a, b_t, c_t, cfg.ssm_chunk)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * x_in.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype) * jax.nn.silu(z)
    # gated RMSNorm (mamba2)
    yf = y.astype(jnp.float32)
    y = (
        yf
        * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
        * p["norm_scale"].astype(jnp.float32)
    ).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, cast(p["out_proj"], cfg))
    if return_state:
        kc = cfg.conv_dim
        conv_state = xbc_pre.astype(jnp.float32)[:, s - kc + 1 :, :]
        return out, {"conv": conv_state, "ssm": h_last}
    return out


def mamba2_init_state(cfg: ModelConfig, batch: int):
    conv_ch = cfg.d_inner + 2 * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_dim - 1, conv_ch), jnp.float32),
        "ssm": jnp.zeros(
            (batch, cfg.n_ssm_heads, cfg.d_state, cfg.ssm_head_dim), jnp.float32
        ),
    }


def mamba2_step(p, x_t, state, cfg: ModelConfig):
    """One decode step. x_t: (B, D)."""
    b, d = x_t.shape
    di, ds, h = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    pdim = cfg.ssm_head_dim
    proj = jnp.einsum("bd,de->be", x_t, cast(p["in_proj"], cfg))
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * ds]
    dt_raw = proj[..., di + di + 2 * ds :]
    conv_state, xbc = conv_step(
        state["conv"], xbc.astype(jnp.float32), p["conv_w"], p["conv_b"]
    )
    xbc = jax.nn.silu(xbc)
    x_in = xbc[..., :di].reshape(b, h, pdim)
    b_t = xbc[..., di : di + ds]
    c_t = xbc[..., di + ds :]
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)  # (B,H)
    hs = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bd,bhp->bhdp", dt, b_t, x_in
    )
    y = jnp.einsum("bd,bhdp->bhp", c_t, hs)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * x_in
    y = y.reshape(b, di).astype(x_t.dtype) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (
        yf
        * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
        * p["norm_scale"].astype(jnp.float32)
    ).astype(x_t.dtype)
    out = jnp.einsum("bi,id->bd", y, cast(p["out_proj"], cfg))
    return out, {"conv": conv_state, "ssm": hs}
