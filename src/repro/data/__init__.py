"""Data plane: synthetic DBpedia-Live-like streams, verbalizer, batching."""
from .changeset_gen import DBpediaLikeGenerator, GeneratorConfig
from .pipeline import ReplicaTokenPipeline
from .verbalizer import Verbalizer

__all__ = [
    "DBpediaLikeGenerator",
    "GeneratorConfig",
    "ReplicaTokenPipeline",
    "Verbalizer",
]
