"""Training-data pipeline fed by an interest-filtered replica.

The full loop (DESIGN.md §4): an evolving source publishes changesets; the
iRap subscription keeps the replica (τ) current; this pipeline re-tokenizes
replica content into fixed-shape LM batches. Data-parallel workers each own
a deterministic shard of the token stream (seeded; elastically recomputable
after scale-up/down, which is what makes the pipeline restart-safe).
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from ..core import TripleStore, to_numpy
from .verbalizer import Verbalizer


class ReplicaTokenPipeline:
    def __init__(
        self,
        verbalizer: Verbalizer,
        batch_size: int,
        seq_len: int,
        seed: int = 0,
        worker: int = 0,
        n_workers: int = 1,
    ):
        self.verb = verbalizer
        self.b, self.s = batch_size, seq_len
        self.seed = seed
        self.worker = worker
        self.n_workers = n_workers
        self._tokens = np.zeros((0,), np.int32)
        self._epoch = 0

    def refresh(self, replica: TripleStore) -> None:
        """Re-tokenize after the subscription applied a changeset."""
        spo = to_numpy(replica)
        self._tokens = self.verb.triples_to_tokens(spo)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        need = self.b * (self.s + 1)
        toks = self._tokens
        if toks.shape[0] < max(need, 8):
            raise StopIteration("replica too small — refresh() first")
        rng = np.random.default_rng(
            (self.seed, self._epoch, self.worker)
        )
        self._epoch += 1
        starts = rng.integers(0, toks.shape[0] - self.s - 1, size=self.b)
        rows = np.stack([toks[st : st + self.s + 1] for st in starts])
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
        }
