"""Synthetic DBpedia-Live-like evolving dataset + changeset stream.

Mirrors the paper's evaluation setting (§4): a large mixed-domain dump with
entity classes (athletes, locations, other people/things), typed attribute
predicates, and a stream of per-day changesets whose adds/removes touch a
configurable fraction of interest-relevant entities — sized so the Football
interest sees ~0.3% and the Location interest a few % of triples, matching
the paper's observed selectivities.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import numpy as np

from ..core.dictionary import Dictionary

# vocabulary of predicates / classes (prefix-style, as in the paper)
P_TYPE = "rdf:type"
P_GOALS = "dbp:goals"
P_NAME = "foaf:name"
P_TEAM = "dbo:team"
P_LABEL = "rdfs:label"
P_LAT = "wgs:lat"
P_LONG = "wgs:long"
P_ABSTRACT = "dbo:abstract"
P_SUBJECT = "dcterms:subject"
P_HOMEPAGE = "foaf:homepage"
C_ATHLETE = "dbo:SoccerPlayer"
C_PLACE = "dbo:Place"
C_PERSON = "foaf:Person"
C_WORK = "dbo:Work"


@dataclasses.dataclass(frozen=True)
class GeneratorConfig:
    n_athletes: int = 400
    n_places: int = 800
    n_other: int = 4000
    n_teams: int = 60
    seed: int = 0
    # per-changeset activity
    adds_per_changeset: int = 600
    removes_per_changeset: int = 300
    athlete_fraction: float = 0.02  # fraction of changeset rows touching athletes
    place_fraction: float = 0.06


class DBpediaLikeGenerator:
    """Seeds an initial dump, then yields ⟨removed, added⟩ changesets."""

    def __init__(self, cfg: GeneratorConfig, dictionary: Dictionary | None = None):
        self.cfg = cfg
        self.dict = dictionary if dictionary is not None else Dictionary()
        self.rng = np.random.default_rng(cfg.seed)
        self._athletes = [f"dbr:Athlete_{i}" for i in range(cfg.n_athletes)]
        self._places = [f"dbr:Place_{i}" for i in range(cfg.n_places)]
        self._others = [f"dbr:Thing_{i}" for i in range(cfg.n_other)]
        self._teams = [f"dbr:Team_{i}" for i in range(cfg.n_teams)]
        self._next_id = 0
        self.current: set = set()  # live triples (string form)

    # ------------------------------------------------------------------
    def _team_triples(self, team: str) -> List[Tuple[str, str, str]]:
        return [(team, P_LABEL, f'"{team} FC"')]

    def _athlete_triples(self, a: str, full: bool) -> List[Tuple[str, str, str]]:
        rows = [(a, P_TYPE, C_ATHLETE), (a, P_NAME, f'"{a}"')]
        team = self._teams[self.rng.integers(len(self._teams))]
        rows.append((a, P_TEAM, team))
        rows += self._team_triples(team)
        if full or self.rng.random() < 0.7:
            rows.append((a, P_GOALS, str(int(self.rng.integers(0, 300)))))
        if self.rng.random() < 0.3:
            rows.append((a, P_HOMEPAGE, f'"http://{a}.example.org"'))
        return rows

    def _place_triples(self, p: str, full: bool) -> List[Tuple[str, str, str]]:
        rows = [
            (p, P_TYPE, C_PLACE),
            (p, P_LABEL, f'"{p}"'),
            (p, P_LAT, f"{self.rng.random() * 180 - 90:.4f}"),
            (p, P_LONG, f"{self.rng.random() * 360 - 180:.4f}"),
        ]
        if full or self.rng.random() < 0.8:
            rows.append((p, P_ABSTRACT, f'"Abstract of {p}"'))
        if self.rng.random() < 0.5:
            rows.append((p, P_SUBJECT, f"dbc:Category_{int(self.rng.integers(40))}"))
        return rows

    def _other_triples(self, o: str) -> List[Tuple[str, str, str]]:
        cls = C_PERSON if self.rng.random() < 0.5 else C_WORK
        rows = [(o, P_TYPE, cls), (o, P_NAME, f'"{o}"')]
        for j in range(int(self.rng.integers(1, 5))):
            rows.append((o, f"dbp:prop{j}", str(int(self.rng.integers(1000)))))
        return rows

    # ------------------------------------------------------------------
    def initial_dump(self) -> np.ndarray:
        rows: List[Tuple[str, str, str]] = []
        for a in self._athletes:
            rows += self._athlete_triples(a, full=True)
        for p in self._places:
            rows += self._place_triples(p, full=True)
        for o in self._others:
            rows += self._other_triples(o)
        self.current = set(rows)
        return self.dict.encode_triples(sorted(self.current))

    def slice_for(self, predicate_filter) -> np.ndarray:
        """Initial RDFSlice-style subset (paper §2): triples passing a filter."""
        rows = sorted(t for t in self.current if predicate_filter(t))
        return self.dict.encode_triples(rows)

    # ------------------------------------------------------------------
    def changeset(self) -> Tuple[np.ndarray, np.ndarray]:
        """One ⟨removed, added⟩ changeset (dictionary-encoded)."""
        cfg, rng = self.cfg, self.rng
        adds: List[Tuple[str, str, str]] = []
        removes: List[Tuple[str, str, str]] = []

        # sort before sampling: ``self.current`` is a Python set, and set
        # iteration order varies with PYTHONHASHSEED across processes —
        # sorting makes every stream a pure function of ``cfg.seed``, so
        # benchmarks and examples reproduce run-to-run
        live = sorted(self.current)
        # removals: random live triples + occasional whole-entity retirement
        if live:
            k = min(cfg.removes_per_changeset, len(live))
            idx = rng.choice(len(live), size=k, replace=False)
            removes += [live[i] for i in idx]

        # adds: entity churn weighted by domain fractions
        n = cfg.adds_per_changeset
        n_ath = int(n * cfg.athlete_fraction)
        n_pl = int(n * cfg.place_fraction)
        for _ in range(max(1, n_ath // 4)):
            a = f"dbr:NewAthlete_{self._next_id}"
            self._next_id += 1
            full = rng.random() < 0.5  # half arrive with partial attribute sets
            adds += self._athlete_triples(a, full=full)
        for _ in range(max(1, n_pl // 5)):
            p = f"dbr:NewPlace_{self._next_id}"
            self._next_id += 1
            adds += self._place_triples(p, full=rng.random() < 0.5)
        # goal updates for existing athletes (remove+add pattern)
        for _ in range(max(1, n_ath // 2)):
            a = self._athletes[rng.integers(len(self._athletes))]
            old = sorted(
                t for t in self.current if t[0] == a and t[1] == P_GOALS
            )
            removes += old
            adds.append((a, P_GOALS, str(int(rng.integers(0, 300)))))
        # bulk uninteresting churn
        while len(adds) < n:
            o = f"dbr:NewThing_{self._next_id}"
            self._next_id += 1
            adds += self._other_triples(o)

        removes = [t for t in sorted(set(removes)) if t in self.current]
        adds = sorted(set(adds) - set(removes))
        self.current -= set(removes)
        self.current |= set(adds)
        return (
            self.dict.encode_triples(sorted(removes)),
            self.dict.encode_triples(adds),
        )

    def stream(self, n: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        for _ in range(n):
            yield self.changeset()
