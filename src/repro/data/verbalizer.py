"""KG-triple verbalizer: interest-filtered replica triples -> token streams.

The training examples (examples/train_kg_lm.py) learn language-model
structure over verbalized triples. Terms hash into disjoint vocab bands so
the mapping is deterministic, collision-bounded, and dictionary-free on the
consumer side.
"""
from __future__ import annotations

import numpy as np

from ..core.dictionary import Dictionary

BOS, EOS, SEP = 0, 1, 2
N_SPECIAL = 3


class Verbalizer:
    def __init__(self, vocab: int, dictionary: Dictionary):
        assert vocab > 64
        self.vocab = vocab
        self.dict = dictionary
        self.band = (vocab - N_SPECIAL) // 3

    def term_token(self, term_id: int, slot: int) -> int:
        return N_SPECIAL + slot * self.band + (term_id % self.band)

    def triples_to_tokens(self, spo: np.ndarray) -> np.ndarray:
        """(N, 3) int32 triple ids -> flat token stream [s p o SEP ...]."""
        n = spo.shape[0]
        if n == 0:
            return np.zeros((0,), np.int32)
        out = np.empty((n, 4), np.int32)
        for k in range(3):
            out[:, k] = N_SPECIAL + k * self.band + (spo[:, k] % self.band)
        out[:, 3] = SEP
        return out.reshape(-1)
