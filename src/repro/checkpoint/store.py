"""Checkpoint store: atomic step snapshots + elastic restore.

Layout (per step)::

    <dir>/step_000123/
        manifest.json      # step, flat key list, shapes/dtypes, mesh shape
        arrays.npz         # one entry per flattened pytree leaf

Writes are atomic (tmp dir + rename) so a crash mid-save never corrupts the
latest checkpoint — the restart path (runtime/trainer.py) always loads the
newest *complete* snapshot. Restore takes a target sharding pytree and
``device_put``s each leaf, so a checkpoint written on one mesh restores onto
another (elastic scale-up/down); multi-host deployments would write one
``arrays.npz`` per host from ``addressable_shards`` — the manifest format
already carries the mesh metadata for that.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointStore:
    def __init__(self, directory: str | Path):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], extra: Dict | None = None):
        tmp = self.dir / f".tmp_step_{step:09d}"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat: Dict[str, np.ndarray] = {}
        struct = {}
        for name, tree in state.items():
            sub = _flatten(tree)
            for k, v in sub.items():
                flat[f"{name}/{k}"] = v
            struct[name] = jax.tree_util.tree_structure(tree)
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc(keep=3)

    def _gc(self, keep: int):
        steps = sorted(self.dir.glob("step_*"))
        for old in steps[:-keep]:
            shutil.rmtree(old)

    # ------------------------------------------------------------------
    def steps(self) -> list:
        """All complete snapshot steps, ascending."""
        return sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
        )

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def load_raw(
        self, step: int
    ) -> Tuple[Dict[str, np.ndarray], Dict]:
        """One snapshot's flat arrays + extra metadata, no template needed.

        The template-free read path (broker recovery): the caller rebuilds
        its own structure from the manifest ``extra`` and the flat
        ``name/key`` array entries.
        """
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "arrays.npz")
        return {k: data[k] for k in data.files}, manifest.get("extra", {})

    def restore(
        self,
        template: Dict[str, Any],
        step: int | None = None,
        shardings: Dict[str, Any] | None = None,
    ) -> Tuple[Dict[str, Any], int]:
        """Restore into the template's structure; optionally reshard.

        ``shardings``: same outer keys as ``template``, pytrees of
        ``jax.sharding.Sharding`` (or None → default placement). This is the
        elastic path: the stored host arrays are device_put with the NEW
        mesh's shardings regardless of what wrote them.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:09d}"
        data = np.load(d / "arrays.npz")
        out = {}
        for name, tree in template.items():
            paths = jax.tree_util.tree_flatten_with_path(tree)
            leaves = []
            for path, leaf in paths[0]:
                key = name + "/" + "/".join(
                    str(getattr(p, "key", getattr(p, "idx", p))) for p in path
                )
                arr = data[key]
                if hasattr(leaf, "dtype"):
                    arr = arr.astype(leaf.dtype)
                leaves.append(arr)
            restored = jax.tree_util.tree_unflatten(paths[1], leaves)
            if shardings and shardings.get(name) is not None:
                restored = jax.device_put(restored, shardings[name])
            out[name] = restored
        return out, step
