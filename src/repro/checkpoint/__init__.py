"""Checkpoint substrate: sharded save/restore + elastic resharding."""
from .store import CheckpointStore

__all__ = ["CheckpointStore"]
