"""Pure-jnp oracles for the Pallas kernels (allclose targets for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PAD = np.int32(np.iinfo(np.int32).max)
WILDCARD = np.int32(-1)


def pattern_bitmask_ref(spo: jax.Array, patterns: jax.Array) -> jax.Array:
    """uint32[N] bitset: bit j set iff row i matches patterns[j].

    ``patterns``: int32[P, 3] with -1 as wildcard. PAD rows match nothing.
    """
    n_pat = patterns.shape[0]
    valid = spo[:, 0] != PAD
    acc = jnp.zeros(spo.shape[0], dtype=jnp.uint32)
    for j in range(n_pat):
        pat = patterns[j]
        m = valid
        for k in range(3):
            m = m & ((pat[k] == WILDCARD) | (spo[:, k] == pat[k]))
        acc = acc | (m.astype(jnp.uint32) << j)
    return acc


def pattern_bitmask_words_ref(spo: jax.Array, patterns: jax.Array) -> jax.Array:
    """uint32[N, W] multi-word bank bitset: word ``w`` carries the match
    bits of ``patterns[32w : 32w + 32]`` (W = ceil(P / 32), min 1).

    Oracle for the single-invocation multi-word kernel
    (:func:`repro.kernels.triple_match.triple_match_words_pallas`) and the
    vectorized XLA fallback: one (N, P) match matrix packed into words,
    bit-identical to chunked per-32-lane :func:`pattern_bitmask_ref` passes.
    """
    n = spo.shape[0]
    n_pat = patterns.shape[0]
    n_words = max(1, -(-n_pat // 32))
    if n_pat == 0:
        return jnp.zeros((n, n_words), jnp.uint32)
    valid = spo[:, 0] != PAD
    m = valid[:, None]
    for k in range(3):
        pk = patterns[:, k][None, :]
        m = m & ((pk == WILDCARD) | (spo[:, k][:, None] == pk))
    pad_p = n_words * 32 - n_pat
    if pad_p:
        m = jnp.concatenate([m, jnp.zeros((n, pad_p), bool)], axis=1)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(
        m.reshape(n, n_words, 32).astype(jnp.uint32) * weights[None, None, :],
        axis=-1,
        dtype=jnp.uint32,
    )


def pattern_bitmask_words_segmented_ref(
    spo: jax.Array, patterns: jax.Array, seg: jax.Array, n_seg: int
) -> jax.Array:
    """uint32[n_seg, N, W] segment-masked multi-word bank bitset.

    ``seg``: int32[N] per-row segment membership bitmap — bit ``f`` set iff
    row ``i`` belongs to segment ``f`` (the broker's delta-encoded frontier
    chain encodes "union row i is in frontier f's composed D" this way).
    Segment ``f``'s plane equals :func:`pattern_bitmask_words_ref` with the
    non-member rows' words forced to zero — the match itself is evaluated
    exactly ONCE per row and composed per segment by masking, which is the
    whole point: ``n_seg`` overlapping row sets cost one bank pass, not
    ``n_seg``. Bits of ``seg`` at or above ``n_seg`` are ignored.

    Oracle for the single-invocation segmented kernel
    (:func:`repro.kernels.triple_match.triple_match_words_segmented_pallas`)
    and the vectorized XLA fallback.
    """
    words = pattern_bitmask_words_ref(spo, patterns)  # (N, W)
    member = (
        (seg[None, :] >> jnp.arange(n_seg, dtype=jnp.int32)[:, None]) & 1
    ) == 1  # (n_seg, N)
    return jnp.where(member[:, :, None], words[None, :, :], jnp.uint32(0))


def pattern_lane_bits_ref(
    spo_b: jax.Array,
    patterns: jax.Array,
    lanes: jax.Array,
    active: jax.Array | None = None,
) -> jax.Array:
    """uint32[R, N] fused bank emit + lane routing + member mask oracle.

    ``spo_b``: int32[R, N, 3] member-stacked rows; ``lanes``: int32[R, nt];
    ``active`` (optional): bool[R]. Member k's local bit ``j`` is bank lane
    ``lanes[k, j]``'s match bit over ``spo_b[k]``; inactive members are all
    zeros. Oracle for
    :func:`repro.kernels.triple_match.triple_match_lanes_pallas`.
    """
    words = jax.vmap(lambda s: pattern_bitmask_words_ref(s, patterns))(spo_b)
    r, n, _ = words.shape
    nt = lanes.shape[1]
    word_idx = jnp.broadcast_to((lanes // 32)[:, None, :], (r, n, nt))
    shift = (lanes % 32).astype(jnp.uint32)[:, None, :]
    g = jnp.take_along_axis(words, word_idx, axis=2)
    bits = ((g >> shift) & jnp.uint32(1)) << jnp.arange(nt, dtype=jnp.uint32)[
        None, None, :
    ]
    out = jnp.sum(bits, axis=2, dtype=jnp.uint32)
    if active is not None:
        out = jnp.where(active[:, None], out, jnp.uint32(0))
    return out


def lane_refine_ref(
    spo: jax.Array,
    words: jax.Array,
    parents: jax.Array,
    residual: jax.Array,
) -> jax.Array:
    """uint32[N, Wv] refined virtual-lane words (the containment-DAG op).

    ``words``: uint32[N, W] real-bank words (:func:`pattern_bitmask_words_ref`
    output); ``parents``: int32[Vp] parent bank lane per virtual slot (-1 =
    dead slot, bits forced to zero); ``residual``: int32[Vp, 3] with the
    child's constants in exactly the slots the parent leaves variable
    (WILDCARD elsewhere). Output word ``w`` bit ``b`` carries virtual slot
    ``v = 32w + b``: the parent lane's match bit ANDed with the residual
    equality predicate — bit-identical to what
    :func:`pattern_bitmask_words_ref` would emit for the materialized child
    rows (child ≡ parent AND residual), at residual-compare cost instead of
    a full bank-width pass. Oracle for
    :func:`repro.kernels.triple_match.lane_refine_pallas` and the XLA
    fallback.
    """
    n = spo.shape[0]
    vp = parents.shape[0]
    n_out = max(1, -(-vp // 32))
    if vp == 0:
        return jnp.zeros((n, n_out), jnp.uint32)
    live = parents >= 0
    p_safe = jnp.maximum(parents, 0)
    g = jnp.take(words, p_safe // 32, axis=1)  # (N, Vp)
    pbit = (g >> (p_safe % 32).astype(jnp.uint32)[None, :]) & jnp.uint32(1)
    m = live[None, :]
    for k in range(3):
        rk = residual[:, k][None, :]
        m = m & ((rk == WILDCARD) | (spo[:, k][:, None] == rk))
    m = m & (pbit == jnp.uint32(1))
    pad_v = n_out * 32 - vp
    if pad_v:
        m = jnp.concatenate([m, jnp.zeros((n, pad_v), bool)], axis=1)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(
        m.reshape(n, n_out, 32).astype(jnp.uint32) * weights[None, None, :],
        axis=-1,
        dtype=jnp.uint32,
    )


def _lex_less(a: jax.Array, b: jax.Array) -> jax.Array:
    s_lt = a[..., 0] < b[..., 0]
    s_eq = a[..., 0] == b[..., 0]
    p_lt = a[..., 1] < b[..., 1]
    p_eq = a[..., 1] == b[..., 1]
    o_lt = a[..., 2] < b[..., 2]
    return s_lt | (s_eq & (p_lt | (p_eq & o_lt)))


def merge_probe_ref(store: jax.Array, queries: jax.Array):
    """Lexicographic searchsorted-left + membership of queries in a sorted store.

    Returns (idx int32[Q], found bool[Q]). ``store``: int32[S, 3] lex-sorted
    with PAD tail; ``queries``: int32[Q, 3] (any order).
    """
    c = store.shape[0]
    q = queries.shape[0]
    lo = jnp.zeros((q,), dtype=jnp.int32)
    hi = jnp.full((q,), c, dtype=jnp.int32)
    iters = max(1, int(np.ceil(np.log2(c + 1))) + 1)

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        row = jnp.take(store, jnp.minimum(mid, c - 1), axis=0)
        go_right = _lex_less(row, queries)
        active = lo < hi
        return (
            jnp.where(active & go_right, mid + 1, lo),
            jnp.where(active & ~go_right, mid, hi),
        )

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    rows = jnp.take(store, jnp.minimum(lo, c - 1), axis=0)
    found = (lo < c) & jnp.all(rows == queries, axis=-1)
    return lo, found
