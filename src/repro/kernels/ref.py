"""Pure-jnp oracles for the Pallas kernels (allclose targets for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PAD = np.int32(np.iinfo(np.int32).max)
WILDCARD = np.int32(-1)


def pattern_bitmask_ref(spo: jax.Array, patterns: jax.Array) -> jax.Array:
    """uint32[N] bitset: bit j set iff row i matches patterns[j].

    ``patterns``: int32[P, 3] with -1 as wildcard. PAD rows match nothing.
    """
    n_pat = patterns.shape[0]
    valid = spo[:, 0] != PAD
    acc = jnp.zeros(spo.shape[0], dtype=jnp.uint32)
    for j in range(n_pat):
        pat = patterns[j]
        m = valid
        for k in range(3):
            m = m & ((pat[k] == WILDCARD) | (spo[:, k] == pat[k]))
        acc = acc | (m.astype(jnp.uint32) << j)
    return acc


def _lex_less(a: jax.Array, b: jax.Array) -> jax.Array:
    s_lt = a[..., 0] < b[..., 0]
    s_eq = a[..., 0] == b[..., 0]
    p_lt = a[..., 1] < b[..., 1]
    p_eq = a[..., 1] == b[..., 1]
    o_lt = a[..., 2] < b[..., 2]
    return s_lt | (s_eq & (p_lt | (p_eq & o_lt)))


def merge_probe_ref(store: jax.Array, queries: jax.Array):
    """Lexicographic searchsorted-left + membership of queries in a sorted store.

    Returns (idx int32[Q], found bool[Q]). ``store``: int32[S, 3] lex-sorted
    with PAD tail; ``queries``: int32[Q, 3] (any order).
    """
    c = store.shape[0]
    q = queries.shape[0]
    lo = jnp.zeros((q,), dtype=jnp.int32)
    hi = jnp.full((q,), c, dtype=jnp.int32)
    iters = max(1, int(np.ceil(np.log2(c + 1))) + 1)

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        row = jnp.take(store, jnp.minimum(mid, c - 1), axis=0)
        go_right = _lex_less(row, queries)
        active = lo < hi
        return (
            jnp.where(active & go_right, mid + 1, lo),
            jnp.where(active & ~go_right, mid, hi),
        )

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    rows = jnp.take(store, jnp.minimum(lo, c - 1), axis=0)
    found = (lo < c) & jnp.all(rows == queries, axis=-1)
    return lo, found
