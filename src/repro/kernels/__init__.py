"""Pallas TPU kernels for the iRap hot spots + XLA fallbacks.

Kernels (each: <name>.py kernel + ops.py wrapper + ref.py oracle):
  * triple_match — fused multi-pattern triple matching (uint32 bitset emit)
  * merge_join   — blocked sort-merge membership probe (candidate assertion)
"""
from . import merge_join, ops, ref, triple_match  # noqa: F401
