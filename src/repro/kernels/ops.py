"""Public jit'd wrappers around the Pallas kernels with XLA fallbacks.

On non-TPU backends Pallas runs in interpret mode (Python, slow) — correct
but not fast — so the default execution path off-TPU is the pure-XLA
reference; the kernels remain the TPU target and are exercised by the test
suite in interpret mode against the oracles in :mod:`repro.kernels.ref`.

Set ``repro.kernels.ops.FORCE_KERNEL = True`` (or pass ``use_kernel=True``)
to route through the Pallas implementations everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import merge_join, ref, triple_match

PAD = ref.PAD
FORCE_KERNEL = False


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _want_kernel(use_kernel: bool | None) -> bool:
    if use_kernel is None:
        return FORCE_KERNEL or _on_tpu()
    return use_kernel


def pattern_bitmask(spo: jax.Array, patterns: jax.Array, *, use_kernel: bool | None = None) -> jax.Array:
    """uint32[N] bitset of pattern matches per triple row."""
    if not _want_kernel(use_kernel):
        return ref.pattern_bitmask_ref(spo, patterns)
    tile = 128 * triple_match.BLOCK_ROWS
    n = spo.shape[0]
    n_pad = -n % tile
    if n_pad:
        spo = jnp.concatenate(
            [spo, jnp.full((n_pad, 3), PAD, dtype=jnp.int32)], axis=0
        )
    out = triple_match.triple_match_pallas(
        spo, patterns, interpret=not _on_tpu()
    )
    return out[:n]


def pattern_bitmask_words(
    spo: jax.Array,
    patterns,
    *,
    matcher=None,
    use_kernel: bool | None = None,
) -> jax.Array:
    """uint32[N, W] multi-word bitset over an arbitrary-size pattern bank.

    One uint32 bitset lane per pattern caps a single word at 32 patterns. A
    multi-interest pattern bank can exceed that, so the bank spans
    ``W = ceil(P / 32)`` words: word ``w`` holds the match bits for
    ``patterns[32w : 32w + 32]``. All W words are produced by a SINGLE
    fused pass over ``spo`` — the Pallas path emits them in one kernel
    invocation (one HBM pass over the triple tiles regardless of bank
    width), the XLA path packs one vectorized (N, P) match matrix.

    ``matcher`` (optional) must have the :func:`pattern_bitmask` signature;
    the broker threads its distribution/testing hook through here so the
    fused path and the per-interest path route through the same primitive —
    with a custom matcher the bank falls back to one chunked pass per word.
    """
    n_pat = patterns.shape[0]
    if matcher is not None:
        n_words = max(1, -(-n_pat // 32))
        words = []
        for w in range(n_words):
            chunk = patterns[w * 32 : (w + 1) * 32]
            if chunk.shape[0] == 0:
                words.append(jnp.zeros((spo.shape[0],), jnp.uint32))
            else:
                words.append(matcher(spo, chunk))
        return jnp.stack(words, axis=1)
    if n_pat == 0 or not _want_kernel(use_kernel):
        return ref.pattern_bitmask_words_ref(spo, patterns)
    tile = 128 * triple_match.BLOCK_ROWS
    n = spo.shape[0]
    n_pad = -n % tile
    if n_pad:
        spo = jnp.concatenate(
            [spo, jnp.full((n_pad, 3), PAD, dtype=jnp.int32)], axis=0
        )
    out = triple_match.triple_match_words_pallas(
        spo, patterns, interpret=not _on_tpu()
    )
    return out.T[:n]


def pattern_bitmask_words_segmented(
    spo: jax.Array,
    patterns: jax.Array,
    seg: jax.Array,
    n_seg: int,
    *,
    matcher=None,
    use_kernel: bool | None = None,
) -> jax.Array:
    """uint32[n_seg, N, W] segment-masked bank bitsets from ONE match pass.

    ``seg``: int32[N] per-row membership bitmap — bit ``f`` set iff row
    ``i`` belongs to segment ``f`` (bits >= ``n_seg`` ignored, ``n_seg <=
    32``). Plane ``f`` equals ``pattern_bitmask_words(spo[members_f])``
    scattered back to the full row space with non-member rows zeroed.

    This is the delta-encoded frontier chain's primitive: the broker hands
    it the lex-sorted union of the distinct D rows across all fired flush
    frontiers plus each frontier's membership bits, so ``F`` overlapping
    frontiers cost one bank pass over the union (the Pallas path masks the
    per-frontier planes while the words are still in registers; the XLA
    path packs one match matrix and masks per plane) instead of the F
    stacked passes of the pre-delta scheduler.

    With a custom ``matcher`` (distribution/testing hook) the words are
    produced by the chunked :func:`pattern_bitmask_words` path — the hook
    observes exactly ONE pass per 32-lane word, never one per segment.
    """
    if not 1 <= n_seg <= 32:
        raise ValueError(f"n_seg must be in [1, 32], got {n_seg}")
    if matcher is not None or patterns.shape[0] == 0 or not _want_kernel(
        use_kernel
    ):
        if matcher is not None:
            words = pattern_bitmask_words(spo, patterns, matcher=matcher)
            member = (
                (seg[None, :] >> jnp.arange(n_seg, dtype=jnp.int32)[:, None])
                & 1
            ) == 1
            return jnp.where(member[:, :, None], words[None], jnp.uint32(0))
        return ref.pattern_bitmask_words_segmented_ref(
            spo, patterns, seg, n_seg
        )
    tile = 128 * triple_match.BLOCK_ROWS
    n = spo.shape[0]
    n_pad = -n % tile
    if n_pad:
        spo = jnp.concatenate(
            [spo, jnp.full((n_pad, 3), PAD, dtype=jnp.int32)], axis=0
        )
        seg = jnp.concatenate(
            [seg, jnp.zeros((n_pad,), dtype=seg.dtype)], axis=0
        )
    out = triple_match.triple_match_words_segmented_pallas(
        spo, patterns, seg, n_seg=n_seg, interpret=not _on_tpu()
    )
    return jnp.swapaxes(out, 1, 2)[:, :n]


def lane_refine(
    spo: jax.Array,
    words: jax.Array,
    parents: jax.Array,
    residual: jax.Array,
    *,
    use_kernel: bool | None = None,
) -> jax.Array:
    """uint32[N, Wv] virtual-lane words refined from real-bank words.

    The interest-subsumption lattice's containment op: virtual lane ``v``
    holds a pattern strictly contained by real bank lane ``parents[v]``
    (child ≡ parent AND ``residual[v]``, the child's constants in exactly
    the slots the parent leaves variable). Instead of widening the bank and
    re-running the full compare loop, the child's words are the parent's
    already-computed bit (gathered out of ``words``: uint32[N, W] from
    :func:`pattern_bitmask_words` over the same ``spo``) ANDed with the
    three-term residual compare — bit-identical to what
    :func:`pattern_bitmask_words` would emit for the materialized child
    patterns. ``parents[v] == -1`` marks a dead slot (bits forced to zero);
    ``Wv = ceil(len(parents) / 32)``, min 1.
    """
    if parents.shape[0] == 0 or not _want_kernel(use_kernel):
        return ref.lane_refine_ref(spo, words, parents, residual)
    tile = 128 * triple_match.BLOCK_ROWS
    n = spo.shape[0]
    n_pad = -n % tile
    if n_pad:
        spo = jnp.concatenate(
            [spo, jnp.full((n_pad, 3), PAD, dtype=jnp.int32)], axis=0
        )
        words = jnp.concatenate(
            [words, jnp.zeros((n_pad, words.shape[1]), jnp.uint32)], axis=0
        )
    out = triple_match.lane_refine_pallas(
        spo, words, parents, residual, interpret=not _on_tpu()
    )
    return out.T[:n]


def pattern_lane_bits_batched(
    spo_b: jax.Array,
    patterns: jax.Array,
    lanes: jax.Array,
    active: jax.Array | None = None,
    *,
    matcher=None,
    use_kernel: bool | None = None,
) -> jax.Array:
    """uint32[R, N] fused bank match + lane routing for a member-stacked
    cohort: member ``k``'s local pattern ``j`` reads bank lane
    ``lanes[k, j]`` over its own rows ``spo_b[k]``; inactive (padding)
    members produce all-zero bits.

    Semantically ``lane_bits_batched(words_per_member, lanes, active)`` with
    ``words_per_member = pattern_bitmask_words`` mapped over members — but
    the Pallas path runs match + routing + masking in ONE kernel, so the
    intermediate uint32[R, N, W] bank words never leave registers. With a
    custom ``matcher`` the composed (unfused) pipeline is used so
    distribution/testing hooks observe every bank pass.
    """
    if matcher is not None:
        words = jax.vmap(
            lambda s: pattern_bitmask_words(s, patterns, matcher=matcher)
        )(spo_b)
        return lane_bits_batched(words, lanes, active=active)
    if patterns.shape[0] == 0 or not _want_kernel(use_kernel):
        return ref.pattern_lane_bits_ref(spo_b, patterns, lanes, active)
    r, n = spo_b.shape[0], spo_b.shape[1]
    tile = 128 * triple_match.BLOCK_ROWS
    n_pad = -n % tile
    if n_pad:
        spo_b = jnp.concatenate(
            [spo_b, jnp.full((r, n_pad, 3), PAD, dtype=jnp.int32)], axis=1
        )
    act = (
        jnp.ones((r, 1), jnp.int32)
        if active is None
        else active.astype(jnp.int32).reshape(r, 1)
    )
    out = triple_match.triple_match_lanes_pallas(
        spo_b, patterns, lanes, act, interpret=not _on_tpu()
    )
    return out[:, :n]


def lane_bits(words: jax.Array, lanes) -> jax.Array:
    """Route bank bitset lanes back to one plan's local pattern numbering.

    ``words``: uint32[N, W] from :func:`pattern_bitmask_words` over a shared
    pattern bank. ``lanes``: static sequence mapping this plan's local
    pattern index ``j`` to its bank lane. Returns uint32[N] with bit ``j``
    set iff bank lane ``lanes[j]`` is set — i.e. exactly what
    ``pattern_bitmask(spo, plan.patterns)`` would have produced.
    """
    acc = jnp.zeros((words.shape[0],), dtype=jnp.uint32)
    for j, lane in enumerate(lanes):
        lane = int(lane)
        bit = (words[:, lane // 32] >> np.uint32(lane % 32)) & np.uint32(1)
        acc = acc | (bit << np.uint32(j))
    return acc


def lane_bits_batched(
    words: jax.Array,
    lanes_arr: jax.Array,
    active: jax.Array | None = None,
    row_mask: jax.Array | None = None,
) -> jax.Array:
    """Batched lane routing for a subscriber cohort.

    ``words``: uint32[N, R, W] bank bitset words (per cohort member, per
    triple row). ``lanes_arr``: int32[N, nt] — member ``k``'s local pattern
    ``j`` reads bank lane ``lanes_arr[k, j]``. Returns uint32[N, R] local
    bitsets: the vectorized equivalent of calling :func:`lane_bits` once per
    member, used by the broker's vmapped cohort evaluation.

    ``active`` (optional): bool[N] member mask. The broker pads cohorts to
    power-of-two sizes so membership churn reuses cached executables; the
    padding lanes are dummy members whose bits are forced to zero here, so
    downstream evaluation sees no candidates and produces empty outputs.

    ``row_mask`` (optional): bool[N, R] per-shard row-ownership mask — the
    sharded broker's variant.  Each mesh device evaluates the same member
    rows but owns only the subset whose hash lands on it; zeroing the other
    rows' bits here partitions candidates, signature scatters, and outputs
    across shards without reshaping any executable input.
    """
    n, r, _ = words.shape
    nt = lanes_arr.shape[1]
    word_idx = jnp.broadcast_to((lanes_arr // 32)[:, None, :], (n, r, nt))
    shift = (lanes_arr % 32).astype(jnp.uint32)[:, None, :]
    g = jnp.take_along_axis(words, word_idx, axis=2)
    bits = ((g >> shift) & jnp.uint32(1)) << jnp.arange(
        nt, dtype=jnp.uint32
    )[None, None, :]
    # lanes occupy disjoint local bit positions, so sum == bitwise OR
    out = jnp.sum(bits, axis=2, dtype=jnp.uint32)
    if active is not None:
        out = jnp.where(active[:, None], out, jnp.uint32(0))
    if row_mask is not None:
        out = jnp.where(row_mask, out, jnp.uint32(0))
    return out


def merge_probe(
    store: jax.Array,
    queries: jax.Array,
    *,
    use_kernel: bool | None = None,
    windowed: bool = False,
):
    """(idx, found) of each query row in a lex-sorted store (original order).

    ``store``: int32[S, 3] lex-sorted with PAD tail. ``queries``: int32[Q, 3]
    any order. ``found`` is bool[Q]; ``idx`` is the searchsorted-left position.

    The kernel path requires every sorted-query block's covering store window
    to fit STORE_BLOCK rows; when that precondition fails (measured host-side
    in eager mode) the call transparently falls back to the XLA path.
    """
    if not _want_kernel(use_kernel):
        return ref.merge_probe_ref(store, queries)

    qb, sb = merge_join.QUERY_BLOCK, merge_join.STORE_BLOCK
    q = queries.shape[0]
    s = store.shape[0]

    # sort queries, pad to block multiples
    perm = jnp.lexsort((queries[:, 2], queries[:, 1], queries[:, 0]))
    qs = queries[perm]
    q_pad = -q % qb
    if q_pad:
        qs = jnp.concatenate([qs, jnp.full((q_pad, 3), PAD, jnp.int32)], axis=0)
    s_pad = -s % sb
    store_p = store
    if s_pad:
        store_p = jnp.concatenate(
            [store, jnp.full((s_pad, 3), PAD, jnp.int32)], axis=0
        )
    sp_len = store_p.shape[0]
    g = qs.shape[0] // qb

    # covering window per query block: position of its first/last query
    firsts = qs[0::qb]
    lasts = qs[qb - 1 :: qb]
    start, _ = ref.merge_probe_ref(store_p, firsts)
    end, _ = ref.merge_probe_ref(store_p, lasts)
    end = jnp.minimum(end + 1, sp_len)
    win_blk = start // sb
    fits = jnp.all(end <= (win_blk + 1) * sb)

    if not jax.core.is_concrete(fits):
        # inside a jit trace we cannot branch on the skew check
        return ref.merge_probe_ref(store, queries)
    if not bool(fits) or sp_len < sb:
        return ref.merge_probe_ref(store, queries)

    if windowed:
        idx_s, found_s = merge_join.merge_probe_windowed(
            store_p, win_blk.astype(jnp.int32), qs, interpret=not _on_tpu()
        )
    else:
        starts = (win_blk * sb).astype(jnp.int32)
        gather = jax.vmap(
            lambda st: jax.lax.dynamic_slice(store_p, (st, 0), (sb, 3))
        )
        windows = gather(starts)
        idx_s, found_s = merge_join.merge_probe_pallas(
            windows, starts, qs, interpret=not _on_tpu()
        )

    idx_s = idx_s[:q]
    found_s = found_s[:q].astype(bool)
    inv = jnp.zeros_like(perm).at[perm].set(jnp.arange(q))
    return idx_s[inv], found_s[inv]
