"""Pallas TPU kernel: fused multi-pattern triple matching (bitset emit).

The iRap hot loop scans every changeset triple against all (<=32) triple
patterns of the registered interests. On TPU we stream structure-of-arrays
(s, p, o) tiles through VMEM and evaluate all patterns per tile on the VPU,
emitting a uint32 bitset per triple — one HBM pass instead of one Jena index
scan per pattern (DESIGN.md §2).

Layout: the ops wrapper reshapes the N-vector columns to (N // 128, 128) so
tiles align with the (8, 128) vreg shape; the block is (BLOCK_ROWS, 128)
= BLOCK_ROWS * 128 triples, 3 * 4B each -> VMEM footprint
3 * BLOCK_ROWS * 512 B + out BLOCK_ROWS * 512 B (BLOCK_ROWS=32: ~64 KiB).
Patterns are a tiny (P, 3) operand replicated to every block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

PAD = np.int32(np.iinfo(np.int32).max)
WILDCARD = np.int32(-1)

BLOCK_ROWS = 32  # x 128 lanes = 4096 triples per block


def _kernel(pat_ref, s_ref, p_ref, o_ref, out_ref, *, n_pat: int):
    s = s_ref[...]
    p = p_ref[...]
    o = o_ref[...]
    valid = s != PAD
    acc = jnp.zeros(s.shape, dtype=jnp.uint32)
    for j in range(n_pat):  # static unroll: all patterns fused in one pass
        ps = pat_ref[j, 0]
        pp = pat_ref[j, 1]
        po = pat_ref[j, 2]
        m = (
            valid
            & ((ps == WILDCARD) | (s == ps))
            & ((pp == WILDCARD) | (p == pp))
            & ((po == WILDCARD) | (o == po))
        )
        acc = acc | (m.astype(jnp.uint32) << j)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def triple_match_pallas(spo: jax.Array, patterns: jax.Array, *, interpret: bool = True) -> jax.Array:
    """uint32[N] pattern bitset for lex-agnostic (N, 3) int32 triples.

    N must be a multiple of 128 * BLOCK_ROWS (the ops wrapper pads).
    """
    n = spo.shape[0]
    n_pat = patterns.shape[0]
    assert n % (128 * BLOCK_ROWS) == 0, n
    rows = n // 128
    s2 = spo[:, 0].reshape(rows, 128)
    p2 = spo[:, 1].reshape(rows, 128)
    o2 = spo[:, 2].reshape(rows, 128)

    grid = (rows // BLOCK_ROWS,)
    col_spec = pl.BlockSpec((BLOCK_ROWS, 128), lambda i: (i, 0))
    pat_spec = pl.BlockSpec((n_pat, 3), lambda i: (0, 0))

    out = pl.pallas_call(
        functools.partial(_kernel, n_pat=n_pat),
        grid=grid,
        in_specs=[pat_spec, col_spec, col_spec, col_spec],
        out_specs=col_spec,
        out_shape=jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
        interpret=interpret,
    )(patterns, s2, p2, o2)
    return out.reshape(n)
