"""Pallas TPU kernels: fused multi-pattern triple matching (bitset emit).

The iRap hot loop scans every changeset triple against all registered triple
patterns. On TPU we stream structure-of-arrays (s, p, o) tiles through VMEM
and evaluate all patterns per tile on the VPU, emitting uint32 bitsets — one
HBM pass over the triple columns instead of one Jena index scan per pattern
(DESIGN.md §2). Three kernels share the tile layout and the unrolled
pattern-compare loop (:func:`_match_words`):

* :func:`triple_match_pallas` — the original single-word kernel: <= 32
  patterns, uint32[N] out.
* :func:`triple_match_words_pallas` — multi-word bank emit: all
  ``W = ceil(P / 32)`` bank words produced in ONE kernel invocation, i.e.
  one HBM pass over the (s, p, o) tiles regardless of bank width,
  uint32[W, N] out (the ops wrapper transposes to uint32[N, W]).
* :func:`triple_match_words_segmented_pallas` — segment-masked multi-word
  emit: the bank words are computed once per tile and composed into up to 32
  per-segment output planes by masking while still in registers (``seg``
  holds one membership bit per segment and row). The broker's delta-encoded
  frontier chain feeds it the distinct-row union of overlapping flush
  frontiers, so ``F`` frontiers cost ONE pass over the union instead of one
  stacked pass per frontier, uint32[F, W, N] out.
* :func:`lane_refine_pallas` — the interest-subsumption lattice's
  containment op: a *virtual* bank lane whose pattern is strictly contained
  by a real lane's pattern (constant where the parent has a variable) never
  occupies bank width — its words are the parent's already-emitted words
  ANDed with the cheap residual-constant compare, uint32[Wv, N] out.
* :func:`triple_match_lanes_pallas` — the broker's fully fused cohort path:
  multi-word emit PLUS bitset-lane routing PLUS the member (padding-lane)
  mask in one kernel. Each cohort member's triple tile is matched against
  the whole bank and its local pattern bits are composed in registers, so
  the intermediate uint32[N, W] bank words never touch HBM at all.

Layout / VMEM math: the ops wrappers reshape the N-vector columns to
(N // 128, 128) so tiles align with the (8, 128) vreg shape; a block is
(BLOCK_ROWS, 128) = BLOCK_ROWS * 128 triples, 3 columns * 4 B each. Per grid
step (BLOCK_ROWS = 32):

  inputs   3 * BLOCK_ROWS * 512 B                    =  48 KiB
  words    out W * BLOCK_ROWS * 512 B (word kernel)  =  16 KiB * W
  lanes    out BLOCK_ROWS * 512 B (lane kernel)      =  16 KiB

The W bank words of the multi-word block live in vector registers between
the compare loop and the store/route step — VMEM holds only the triple tile
and the final output block, so footprint grows with W only through the
(tiny, replicated) ``(32 W, 3)`` pattern operand and the word-kernel output
block. The lane-routing kernel additionally replicates the ``(R, nt)`` lane
map and the ``(R, 1)`` member mask, reading one row per member grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

PAD = np.int32(np.iinfo(np.int32).max)
WILDCARD = np.int32(-1)

BLOCK_ROWS = 32  # x 128 lanes = 4096 triples per block


def _match_words(pat_ref, s, p, o, n_pat: int):
    """All ``ceil(n_pat / 32)`` uint32 bank words for one (s, p, o) tile.

    Static unroll over the whole bank: every pattern compare reuses the same
    three VMEM-resident columns, so the full multi-word emit costs one pass
    over the tile. Returns a list of per-word uint32 accumulators (vreg
    resident). Tombstoned / padding bank rows are all-PAD and can never
    match a valid triple (PAD rows themselves are masked via ``valid``).
    """
    valid = s != PAD
    n_words = max(1, -(-n_pat // 32))
    accs = []
    for w in range(n_words):
        acc = jnp.zeros(s.shape, dtype=jnp.uint32)
        for j in range(w * 32, min(n_pat, w * 32 + 32)):
            ps = pat_ref[j, 0]
            pp = pat_ref[j, 1]
            po = pat_ref[j, 2]
            m = (
                valid
                & ((ps == WILDCARD) | (s == ps))
                & ((pp == WILDCARD) | (p == pp))
                & ((po == WILDCARD) | (o == po))
            )
            acc = acc | (m.astype(jnp.uint32) << (j - w * 32))
        accs.append(acc)
    return accs


def _kernel(pat_ref, s_ref, p_ref, o_ref, out_ref, *, n_pat: int):
    out_ref[...] = _match_words(pat_ref, s_ref[...], p_ref[...], o_ref[...], n_pat)[0]


def _kernel_words(pat_ref, s_ref, p_ref, o_ref, out_ref, *, n_pat: int):
    accs = _match_words(pat_ref, s_ref[...], p_ref[...], o_ref[...], n_pat)
    for w, acc in enumerate(accs):
        out_ref[w] = acc


def _kernel_words_segmented(
    pat_ref, seg_ref, s_ref, p_ref, o_ref, out_ref, *, n_pat: int, n_seg: int
):
    """Segment-masked multi-word emit: one match, ``n_seg`` composed planes.

    The bank words for the tile are computed ONCE (vreg resident) by the
    shared compare loop; each segment's output plane is the same words with
    the rows outside that segment forced to zero (``seg`` carries one
    membership bit per segment and row). n_seg overlapping row subsets
    therefore cost one pass over the (s, p, o) tile, not n_seg.
    """
    accs = _match_words(pat_ref, s_ref[...], p_ref[...], o_ref[...], n_pat)
    seg = seg_ref[...]
    zero = jnp.zeros(seg.shape, dtype=jnp.uint32)
    for f in range(n_seg):
        m = ((seg >> f) & 1) == 1
        for w, acc in enumerate(accs):
            out_ref[f, w] = jnp.where(m, acc, zero)


def _kernel_lanes(
    pat_ref,
    lanes_ref,
    act_ref,
    s_ref,
    p_ref,
    o_ref,
    out_ref,
    *,
    n_pat: int,
    n_tgt: int,
):
    """Fused bank emit + lane routing + member mask for ONE cohort member.

    The member's lane map row arrives as a (1, n_tgt) block; bank words stay
    in registers and each local pattern bit is selected out of its word via
    a static unroll over the W words (lane values are traced, so the word
    choice is a select chain, not a dynamic index).
    """
    accs = _match_words(pat_ref, s_ref[0], p_ref[0], o_ref[0], n_pat)
    local = jnp.zeros(s_ref[0].shape, dtype=jnp.uint32)
    for t in range(n_tgt):
        lane = lanes_ref[0, t]
        wi = lane // 32
        sh = (lane % 32).astype(jnp.uint32)
        word = accs[0]
        for w in range(1, len(accs)):
            word = jnp.where(wi == w, accs[w], word)
        local = local | (((word >> sh) & jnp.uint32(1)) << jnp.uint32(t))
    active = act_ref[0, 0] != 0
    out_ref[0] = jnp.where(active, local, jnp.zeros_like(local))


@functools.partial(jax.jit, static_argnames=("interpret",))
def triple_match_pallas(spo: jax.Array, patterns: jax.Array, *, interpret: bool = True) -> jax.Array:
    """uint32[N] pattern bitset for lex-agnostic (N, 3) int32 triples.

    N must be a multiple of 128 * BLOCK_ROWS (the ops wrapper pads).
    """
    n = spo.shape[0]
    n_pat = patterns.shape[0]
    assert n % (128 * BLOCK_ROWS) == 0, n
    rows = n // 128
    s2 = spo[:, 0].reshape(rows, 128)
    p2 = spo[:, 1].reshape(rows, 128)
    o2 = spo[:, 2].reshape(rows, 128)

    grid = (rows // BLOCK_ROWS,)
    col_spec = pl.BlockSpec((BLOCK_ROWS, 128), lambda i: (i, 0))
    pat_spec = pl.BlockSpec((n_pat, 3), lambda i: (0, 0))

    out = pl.pallas_call(
        functools.partial(_kernel, n_pat=n_pat),
        grid=grid,
        in_specs=[pat_spec, col_spec, col_spec, col_spec],
        out_specs=col_spec,
        out_shape=jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
        interpret=interpret,
    )(patterns, s2, p2, o2)
    return out.reshape(n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def triple_match_words_pallas(
    spo: jax.Array, patterns: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """uint32[W, N] multi-word bank bitset in one kernel invocation.

    ``W = ceil(P / 32)`` (min 1): word ``w`` carries the match bits of
    ``patterns[32w : 32w + 32]``. One HBM pass over the (s, p, o) tiles
    regardless of bank width; N must be a multiple of 128 * BLOCK_ROWS.
    """
    n = spo.shape[0]
    n_pat = patterns.shape[0]
    n_words = max(1, -(-n_pat // 32))
    assert n % (128 * BLOCK_ROWS) == 0, n
    rows = n // 128
    s2 = spo[:, 0].reshape(rows, 128)
    p2 = spo[:, 1].reshape(rows, 128)
    o2 = spo[:, 2].reshape(rows, 128)

    grid = (rows // BLOCK_ROWS,)
    col_spec = pl.BlockSpec((BLOCK_ROWS, 128), lambda i: (i, 0))
    pat_spec = pl.BlockSpec((max(1, n_pat), 3), lambda i: (0, 0))
    out_spec = pl.BlockSpec((n_words, BLOCK_ROWS, 128), lambda i: (0, i, 0))

    out = pl.pallas_call(
        functools.partial(_kernel_words, n_pat=n_pat),
        grid=grid,
        in_specs=[pat_spec, col_spec, col_spec, col_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n_words, rows, 128), jnp.uint32),
        interpret=interpret,
    )(patterns, s2, p2, o2)
    return out.reshape(n_words, n)


@functools.partial(jax.jit, static_argnames=("n_seg", "interpret"))
def triple_match_words_segmented_pallas(
    spo: jax.Array,
    patterns: jax.Array,
    seg: jax.Array,
    *,
    n_seg: int,
    interpret: bool = True,
) -> jax.Array:
    """uint32[n_seg, W, N] segment-masked bank bitset in one invocation.

    ``seg``: int32[N] membership bitmap (bit ``f`` = row belongs to segment
    ``f``; bits >= ``n_seg`` ignored). Each of the ``n_seg`` output planes
    equals :func:`triple_match_words_pallas` with non-member rows zeroed,
    but the pattern-compare loop runs ONCE per tile — the broker's
    delta-encoded frontier chain uses this to match the distinct-row union
    of overlapping flush frontiers a single time and compose the
    per-frontier words by masking in registers. ``n_seg <= 32``; N must be
    a multiple of 128 * BLOCK_ROWS.
    """
    n = spo.shape[0]
    n_pat = patterns.shape[0]
    n_words = max(1, -(-n_pat // 32))
    assert 1 <= n_seg <= 32, n_seg
    assert n % (128 * BLOCK_ROWS) == 0, n
    rows = n // 128
    s2 = spo[:, 0].reshape(rows, 128)
    p2 = spo[:, 1].reshape(rows, 128)
    o2 = spo[:, 2].reshape(rows, 128)
    g2 = seg.astype(jnp.int32).reshape(rows, 128)

    grid = (rows // BLOCK_ROWS,)
    col_spec = pl.BlockSpec((BLOCK_ROWS, 128), lambda i: (i, 0))
    pat_spec = pl.BlockSpec((max(1, n_pat), 3), lambda i: (0, 0))
    out_spec = pl.BlockSpec(
        (n_seg, n_words, BLOCK_ROWS, 128), lambda i: (0, 0, i, 0)
    )

    out = pl.pallas_call(
        functools.partial(
            _kernel_words_segmented, n_pat=n_pat, n_seg=n_seg
        ),
        grid=grid,
        in_specs=[pat_spec, col_spec, col_spec, col_spec, col_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n_seg, n_words, rows, 128), jnp.uint32),
        interpret=interpret,
    )(patterns, g2, s2, p2, o2)
    return out.reshape(n_seg, n_words, n)


def _kernel_refine(
    par_ref,
    res_ref,
    w_ref,
    s_ref,
    p_ref,
    o_ref,
    out_ref,
    *,
    n_virt: int,
    n_words_in: int,
):
    """Containment-DAG refinement: parent word bit AND residual compare.

    Virtual slot ``v`` gathers its parent bank lane's bit out of the
    already-computed real-bank words (lane values are traced, so the word
    choice is a select chain over the ``n_words_in`` input planes) and ANDs
    the child's residual constant compares — the three-term predicate the
    parent left unconstrained. Dead slots (parent -1) are forced to zero.
    PAD rows need no extra mask: the parent bit is already zero for them.
    """
    s = s_ref[...]
    p = p_ref[...]
    o = o_ref[...]
    n_out = max(1, -(-n_virt // 32))
    for wo in range(n_out):
        acc = jnp.zeros(s.shape, dtype=jnp.uint32)
        for v in range(wo * 32, min(n_virt, wo * 32 + 32)):
            par = par_ref[v, 0]
            wi = par // 32
            sh = (par % 32).astype(jnp.uint32)
            word = w_ref[0]
            for w in range(1, n_words_in):
                word = jnp.where(wi == w, w_ref[w], word)
            pbit = (word >> sh) & jnp.uint32(1)
            rs = res_ref[v, 0]
            rp = res_ref[v, 1]
            ro = res_ref[v, 2]
            m = (
                (pbit == jnp.uint32(1))
                & (par >= 0)
                & ((rs == WILDCARD) | (s == rs))
                & ((rp == WILDCARD) | (p == rp))
                & ((ro == WILDCARD) | (o == ro))
            )
            acc = acc | (m.astype(jnp.uint32) << (v - wo * 32))
        out_ref[wo] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def lane_refine_pallas(
    spo: jax.Array,
    words: jax.Array,
    parents: jax.Array,
    residual: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """uint32[Wv, N] refined virtual-lane words from real-bank words.

    ``words``: uint32[N, W] real-bank planes (PAD rows must already be
    zero, as :func:`triple_match_words_pallas` guarantees); ``parents``:
    int32[Vp] parent bank lane per virtual slot (-1 = dead); ``residual``:
    int32[Vp, 3] child constants in the parent's variable slots (WILDCARD
    elsewhere). Bit-identical to matching the materialized child patterns
    with the words kernel, at residual-compare cost — no bank-width pass.
    ``Wv = ceil(Vp / 32)``; N must be a multiple of 128 * BLOCK_ROWS.
    """
    n = spo.shape[0]
    vp = parents.shape[0]
    n_words_in = words.shape[1]
    n_out = max(1, -(-vp // 32))
    assert n % (128 * BLOCK_ROWS) == 0, n
    rows = n // 128
    s2 = spo[:, 0].reshape(rows, 128)
    p2 = spo[:, 1].reshape(rows, 128)
    o2 = spo[:, 2].reshape(rows, 128)
    w2 = words.T.reshape(n_words_in, rows, 128)
    par2 = parents.reshape(vp, 1)

    grid = (rows // BLOCK_ROWS,)
    col_spec = pl.BlockSpec((BLOCK_ROWS, 128), lambda i: (i, 0))
    par_spec = pl.BlockSpec((vp, 1), lambda i: (0, 0))
    res_spec = pl.BlockSpec((vp, 3), lambda i: (0, 0))
    w_spec = pl.BlockSpec((n_words_in, BLOCK_ROWS, 128), lambda i: (0, i, 0))
    out_spec = pl.BlockSpec((n_out, BLOCK_ROWS, 128), lambda i: (0, i, 0))

    out = pl.pallas_call(
        functools.partial(
            _kernel_refine, n_virt=vp, n_words_in=n_words_in
        ),
        grid=grid,
        in_specs=[par_spec, res_spec, w_spec, col_spec, col_spec, col_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n_out, rows, 128), jnp.uint32),
        interpret=interpret,
    )(par2, residual, w2, s2, p2, o2)
    return out.reshape(n_out, n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def triple_match_lanes_pallas(
    spo_b: jax.Array,
    patterns: jax.Array,
    lanes: jax.Array,
    active: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """uint32[R, N] fused multi-word emit + lane routing for a cohort.

    ``spo_b``: int32[R, N, 3] member-stacked triple rows; ``lanes``:
    int32[R, nt] member k's local pattern j reads bank lane ``lanes[k, j]``;
    ``active``: int32[R, 1] member mask (0 = padding lane, bits forced to
    zero). Equivalent to emitting the bank words per member and routing via
    :func:`repro.kernels.ops.lane_bits_batched`, minus the HBM round trip of
    the intermediate words. N must be a multiple of 128 * BLOCK_ROWS.
    """
    r, n = spo_b.shape[0], spo_b.shape[1]
    n_pat = patterns.shape[0]
    n_tgt = lanes.shape[1]
    assert n % (128 * BLOCK_ROWS) == 0, n
    rows = n // 128
    s2 = spo_b[:, :, 0].reshape(r, rows, 128)
    p2 = spo_b[:, :, 1].reshape(r, rows, 128)
    o2 = spo_b[:, :, 2].reshape(r, rows, 128)

    grid = (r, rows // BLOCK_ROWS)
    col_spec = pl.BlockSpec((1, BLOCK_ROWS, 128), lambda k, i: (k, i, 0))
    pat_spec = pl.BlockSpec((max(1, n_pat), 3), lambda k, i: (0, 0))
    lane_spec = pl.BlockSpec((1, n_tgt), lambda k, i: (k, 0))
    act_spec = pl.BlockSpec((1, 1), lambda k, i: (k, 0))

    out = pl.pallas_call(
        functools.partial(_kernel_lanes, n_pat=n_pat, n_tgt=n_tgt),
        grid=grid,
        in_specs=[pat_spec, lane_spec, act_spec, col_spec, col_spec, col_spec],
        out_specs=col_spec,
        out_shape=jax.ShapeDtypeStruct((r, rows, 128), jnp.uint32),
        interpret=interpret,
    )(patterns, lanes, active, s2, p2, o2)
    return out.reshape(r, n)
