"""Pallas TPU kernel: blocked sort-merge membership probe.

The iRap candidate-assertion step probes the lex-sorted target store for
millions of (binding-substituted) pattern rows. The Jena original walks
B-trees (pointer chasing); the TPU-native plan (DESIGN.md §2) sorts the probe
batch so each query block touches a *contiguous* store window, which is
block-loaded into VMEM and searched there with a vectorized binary search:
log2(STORE_BLOCK) VMEM gathers instead of log2(N) HBM round-trips per query.

Two variants:
  * :func:`merge_probe_pallas` — the ops wrapper materializes each query
    block's store window into a (G, STORE_BLOCK, 3) array (the XLA gather is
    the DMA stand-in); fully static BlockSpecs, works everywhere.
  * :func:`merge_probe_windowed` — TPU production path: per-block window ids
    arrive via scalar prefetch and the store BlockSpec index_map streams the
    right window straight from HBM (no materialization).

Skewed batches whose covering window exceeds STORE_BLOCK fall back to the XLA
path in ops.py (production would multi-pass the rare fat blocks).

VMEM per grid step: queries 12 KiB + window 24 KiB + outputs 8 KiB (defaults).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

PAD = np.int32(np.iinfo(np.int32).max)

QUERY_BLOCK = 1024  # queries per grid step (8 x 128 lanes)
STORE_BLOCK = 2048  # store rows resident in VMEM per grid step


def _lex_less_cols(as_, ap, ao, bs, bp, bo):
    return (as_ < bs) | ((as_ == bs) & ((ap < bp) | ((ap == bp) & (ao < bo))))


def _search_window(q_ref, ss, sp, so):
    """Vectorized binary search of the query block inside one VMEM window."""
    qs = q_ref[:, 0]
    qp = q_ref[:, 1]
    qo = q_ref[:, 2]
    lo = jnp.zeros(qs.shape, dtype=jnp.int32)
    hi = jnp.full(qs.shape, STORE_BLOCK, dtype=jnp.int32)
    for _ in range(int(np.log2(STORE_BLOCK)) + 1):  # static unroll in VMEM
        mid = (lo + hi) // 2
        midc = jnp.minimum(mid, STORE_BLOCK - 1)
        rs = jnp.take(ss, midc)
        rp = jnp.take(sp, midc)
        ro = jnp.take(so, midc)
        go_right = _lex_less_cols(rs, rp, ro, qs, qp, qo)
        active = lo < hi
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    loc = jnp.minimum(lo, STORE_BLOCK - 1)
    found = (
        (lo < STORE_BLOCK)
        & (jnp.take(ss, loc) == qs)
        & (jnp.take(sp, loc) == qp)
        & (jnp.take(so, loc) == qo)
        & (qs != PAD)  # padded queries never match padded store rows
    )
    return lo, found


def _kernel_materialized(starts_ref, q_ref, win_ref, idx_ref, found_ref):
    ss = win_ref[0, :, 0]
    sp = win_ref[0, :, 1]
    so = win_ref[0, :, 2]
    lo, found = _search_window(q_ref, ss, sp, so)
    base = starts_ref[pl.program_id(0)]
    idx_ref[...] = lo + base
    found_ref[...] = found.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_probe_pallas(
    windows: jax.Array,
    window_starts: jax.Array,
    queries_sorted: jax.Array,
    *,
    interpret: bool = True,
):
    """(idx int32[Q], found int32[Q]) for sorted queries vs per-block windows.

    ``windows``: int32[G, STORE_BLOCK, 3] — covering store window per query
    block. ``window_starts``: int32[G] — global row offset of each window.
    ``queries_sorted``: int32[G * QUERY_BLOCK, 3], lex-sorted, PAD-padded.
    """
    q = queries_sorted.shape[0]
    g = windows.shape[0]
    assert q == g * QUERY_BLOCK, (q, g)
    assert windows.shape[1] == STORE_BLOCK

    idx, found = pl.pallas_call(
        _kernel_materialized,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((g,), lambda i: (0,)),
            pl.BlockSpec((QUERY_BLOCK, 3), lambda i: (i, 0)),
            pl.BlockSpec((1, STORE_BLOCK, 3), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((QUERY_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((QUERY_BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        interpret=interpret,
    )(window_starts, queries_sorted, windows)
    return idx, found


def _kernel_prefetch(win_ref, q_ref, store_ref, idx_ref, found_ref):
    ss = store_ref[:, 0]
    sp = store_ref[:, 1]
    so = store_ref[:, 2]
    lo, found = _search_window(q_ref, ss, sp, so)
    base = win_ref[pl.program_id(0)] * STORE_BLOCK
    idx_ref[...] = lo + base
    found_ref[...] = found.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_probe_windowed(
    store: jax.Array,
    window_blocks: jax.Array,
    queries_sorted: jax.Array,
    *,
    interpret: bool = True,
):
    """Scalar-prefetch production variant: stream one store window per block.

    ``window_blocks``: int32[G] — STORE_BLOCK-granular block index of the
    covering window; the store BlockSpec index_map reads it from the prefetch
    operand, so each grid step DMAs exactly one window from HBM.
    """
    from jax.experimental.pallas import tpu as pltpu

    q = queries_sorted.shape[0]
    s = store.shape[0]
    g = window_blocks.shape[0]
    assert q == g * QUERY_BLOCK and s % STORE_BLOCK == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((QUERY_BLOCK, 3), lambda i, win: (i, 0)),
            pl.BlockSpec((STORE_BLOCK, 3), lambda i, win: (win[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((QUERY_BLOCK,), lambda i, win: (i,)),
            pl.BlockSpec((QUERY_BLOCK,), lambda i, win: (i,)),
        ],
    )

    idx, found = pl.pallas_call(
        _kernel_prefetch,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        interpret=interpret,
    )(window_blocks, queries_sorted, store)
    return idx, found
