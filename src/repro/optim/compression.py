"""Gradient compression: error-feedback int8 quantization.

Two entry points:
  * :class:`ErrorFeedbackInt8` — host-side wrapper around any optimizer:
    quantize grads to int8 (per-leaf scale) before the update, carrying the
    quantization residual forward (Karimireddy et al., "EF-SGD"). This models
    a compressed gradient all-reduce: what the update sees is exactly what a
    decompress-after-reduce would produce.
  * :func:`compressed_psum` — the explicit shard_map collective: quantize,
    psum int32, dequantize — used by the manual-collective train-step variant
    and its equivalence test.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed mean-reduce across ``axis_name`` (inside shard_map).

    Scales are psum'd in f32 (negligible bytes); payload moves as int8 —
    a 4x wire reduction vs f32 ring all-reduce.
    """
    q, scale = quantize_int8(g)
    n = jax.lax.psum(1, axis_name)
    total = jax.lax.psum(q.astype(jnp.int32) * 1, axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)
    # each shard contributed ~q*scale; approximate the sum with the mean scale
    return total.astype(jnp.float32) * (scale_sum / n) / n


@dataclasses.dataclass(frozen=True)
class ErrorFeedbackInt8:
    """opt wrapper: grads -> EF-int8 -> inner optimizer."""

    inner: Any  # AdamW-like: init/update

    def init(self, params):
        return {
            "inner": self.inner.init(params),
            "residual": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ),
        }

    def update(self, grads, state, params):
        def comp(g, e):
            corrected = g.astype(jnp.float32) + e
            q, scale = quantize_int8(corrected)
            deq = dequantize_int8(q, scale)
            return deq, corrected - deq

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(state["residual"])
        pairs = [comp(g, e) for g, e in zip(flat_g, flat_e)]
        deq = treedef.unflatten([p[0] for p in pairs])
        resid = treedef.unflatten([p[1] for p in pairs])
        new_p, inner_state, gn = self.inner.update(deq, state["inner"], params)
        return new_p, {"inner": inner_state, "residual": resid}, gn
