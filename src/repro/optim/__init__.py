"""Optimizer substrate: AdamW, schedules, gradient compression."""
from .adamw import AdamW, clip_by_global_norm
from .schedule import constant, cosine_warmup

__all__ = ["AdamW", "clip_by_global_norm", "constant", "cosine_warmup"]
