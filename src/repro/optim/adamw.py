"""AdamW with decoupled weight decay + global-norm clipping (pure pytrees).

No optax dependency: the optimizer state mirrors the param pytree (so the
sharding plan for params transfers 1:1 to m/v — ZeRO-3 style when params are
FSDP-sharded), which the dry-run and checkpoint layers rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    max_grad_norm: float = 0.0  # 0 = no clipping

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state, params) -> Tuple[Any, Any, jax.Array]:
        """Returns (new_params, new_state, grad_norm)."""
        gn = jnp.zeros((), jnp.float32)
        if self.max_grad_norm:
            grads, gn = clip_by_global_norm(grads, self.max_grad_norm)
        step = state["step"] + 1
        lr = self._lr(step)
        c1 = 1.0 - self.b1**step.astype(jnp.float32)
        c2 = 1.0 - self.b2**step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m2 = self.b1 * m + (1 - self.b1) * g32
            v2 = self.b2 * v + (1 - self.b2) * jnp.square(g32)
            mh = m2 / c1
            vh = v2 / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, gn
