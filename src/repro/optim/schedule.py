"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(peak: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)

    return f
