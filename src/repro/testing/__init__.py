"""Deterministic test instrumentation for the repro stack.

:mod:`repro.testing.faults` is the fault-injection harness behind the
broker durability tests (tests/test_broker_recovery.py): seeded fake
clocks, scripted/flaky delivery transports, journal crash/corruption
helpers, and bit-exact broker state capture.
"""
from .faults import (
    CapturingJournal,
    FakeClock,
    ScriptedTransport,
    assert_state_equal,
    broker_state,
    corrupt_tail,
    crash_at_record,
    tear_tail,
    tiny_caps,
)

__all__ = [
    "CapturingJournal",
    "FakeClock",
    "ScriptedTransport",
    "assert_state_equal",
    "broker_state",
    "corrupt_tail",
    "crash_at_record",
    "tear_tail",
    "tiny_caps",
]
