"""Deterministic, seedable fault-injection harness for broker durability.

Everything here is driven by explicit seeds and injected clocks — no wall
time, no ambient randomness — so every failure schedule is reproducible
and the recovery goldens can pin exact backoff sequences and crash
points. The harness covers the four fault families the durable broker
must survive (tests/test_broker_recovery.py):

* **crash at a record boundary** — :func:`crash_at_record` copies a
  journal directory truncated to its first k records (whole frames, via
  :func:`repro.core.journal.scan_segment`), simulating a process killed
  between appends; :class:`CapturingJournal` invokes a callback *before*
  each append, which is where the crash-at-every-boundary property
  captures the pre-append broker state each record must reproduce;
* **torn / corrupt tails** — :func:`tear_tail` chops bytes off the last
  segment (a partially-flushed frame), :func:`corrupt_tail` flips seeded
  bytes inside the last frame (bit rot / garbled flush); both must
  truncate on open, never crash recovery;
* **delivery faults** — :class:`ScriptedTransport` plays per-subscriber
  outcome scripts (``"ok"`` / ``"fail"`` / ``"timeout"``, the latter
  advancing an injected :class:`FakeClock` past the channel's
  ``timeout_s``), driving retry/backoff/quarantine schedules
  deterministically;
* **forced overflow** — :func:`tiny_caps` returns deliberately tiny
  :class:`~repro.core.propagation.StepCapacities` so capacity-overflow
  retry paths (and the bounded degraded-fire ceiling) trigger on small
  inputs.

:func:`broker_state` / :func:`assert_state_equal` capture a broker's
complete observable state (sequence clock, frontiers, pending composed
batches, per-subscriber τ/ρ rows) as host arrays for the bit-identity
assertions the recovery contract is stated in.
"""
from __future__ import annotations

import random
import shutil
from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.journal import (
    ChangesetJournal,
    _HEADER,
    scan_segment,
)
from ..core.propagation import StepCapacities
from ..core.triples import to_numpy


class FakeClock:
    """Injectable monotonic clock: ``clock()`` reads, ``sleep``/``advance``
    move time forward. Passing the same instance as a channel's ``clock``
    and ``sleep`` makes backoff schedules pure arithmetic."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += float(dt)

    def sleep(self, dt: float) -> None:
        if dt > 0:
            self.now += float(dt)


class ScriptedTransport:
    """Delivery callback that plays per-subscriber outcome scripts.

    ``scripts`` maps a subscriber ``jid`` to a list of outcomes consumed
    one per transport *attempt*: ``"ok"`` succeeds, ``"fail"`` raises,
    ``"timeout"`` advances ``clock`` by ``timeout_advance`` and succeeds
    (so only a channel with ``timeout_s < timeout_advance`` counts it as
    failed — a slow call, not a dead one). A subscriber past the end of
    its script (or absent) gets ``default``. Every attempt is recorded in
    ``log`` as ``(jid, outcome)`` and successful deliveries keep their
    outputs in ``delivered[jid]``.
    """

    def __init__(
        self,
        scripts: Optional[Dict[int, List[str]]] = None,
        default: str = "ok",
        clock: Optional[FakeClock] = None,
        timeout_advance: float = 1.0,
    ):
        self.scripts = {j: list(s) for j, s in (scripts or {}).items()}
        self.default = default
        self.clock = clock
        self.timeout_advance = timeout_advance
        self.log: List[tuple] = []
        self.delivered: Dict[int, List[object]] = {}

    def __call__(self, sub, outputs) -> None:
        script = self.scripts.get(sub.jid)
        outcome = script.pop(0) if script else self.default
        self.log.append((sub.jid, outcome))
        if outcome == "fail":
            raise RuntimeError(f"scripted delivery failure for {sub.jid}")
        if outcome == "timeout" and self.clock is not None:
            self.clock.advance(self.timeout_advance)
        self.delivered.setdefault(sub.jid, []).append(outputs)


class CapturingJournal(ChangesetJournal):
    """Journal that reports each record's seq *before* writing its frame.

    ``on_append(seq)`` fires with the broker state exactly as it stands at
    the boundary *before* record ``seq`` becomes durable — which is the
    state a crash-at-``seq - 1`` recovery must reproduce. The
    crash-at-every-boundary property snapshots :func:`broker_state` here.
    """

    def __init__(self, *args, on_append: Optional[Callable] = None, **kw):
        super().__init__(*args, **kw)
        self.on_append = on_append

    def append(self, kind, meta=None, arrays=None, seq=None):
        if self.on_append is not None:
            self.on_append(
                self.last_seq + 1 if seq is None else seq, kind
            )
        return super().append(kind, meta=meta, arrays=arrays, seq=seq)


# ---------------------------------------------------------------------------
# journal fault injection
# ---------------------------------------------------------------------------

def _ordered_segments(directory: Path) -> List[Path]:
    return sorted(
        Path(directory).glob("wal_*.seg"),
        key=lambda p: int(p.name.split("_")[1].split(".")[0]),
    )


def crash_at_record(src: Path, dst: Path, k: int) -> int:
    """Copy journal ``src`` to ``dst`` keeping only its first ``k`` records.

    Truncation happens on whole-frame boundaries, simulating a process
    killed between append ``k`` and append ``k + 1`` (every prior fsync
    completed, nothing after exists). Returns how many records survived
    (``min(k, total)``).
    """
    src, dst = Path(src), Path(dst)
    if dst.exists():
        shutil.rmtree(dst)
    dst.mkdir(parents=True)
    kept = 0
    for seg in _ordered_segments(src):
        entries, _, _ = scan_segment(seg)
        if kept >= k:
            break
        take = entries[: k - kept]
        if not take:
            break
        data = seg.read_bytes()[: take[-1][1]]
        (dst / seg.name).write_bytes(data)
        kept += len(take)
    if kept == 0:
        # crash before the first record: an empty journal directory
        segs = _ordered_segments(src)
        if segs:
            (dst / segs[0].name).write_bytes(_HEADER)
    return kept


def tear_tail(directory: Path, n_bytes: int) -> int:
    """Chop ``n_bytes`` off the newest segment (a partially-flushed frame).

    Returns how many bytes were actually removed (the segment is never
    torn past its 8-byte header, mirroring what an O_APPEND crash can
    produce)."""
    segs = _ordered_segments(directory)
    if not segs:
        return 0
    seg = segs[-1]
    size = seg.stat().st_size
    cut = min(int(n_bytes), max(0, size - len(_HEADER)))
    with open(seg, "r+b") as f:
        f.truncate(size - cut)
    return cut


def corrupt_tail(directory: Path, seed: int = 0, n_flips: int = 4) -> int:
    """Flip seeded bytes inside the newest segment's last frame (bit rot).

    The CRC must catch this: opening the journal afterwards truncates the
    corrupted frame instead of decoding garbage. Returns the number of
    bytes flipped (0 when there is no frame to corrupt)."""
    segs = _ordered_segments(directory)
    if not segs:
        return 0
    seg = segs[-1]
    entries, good_end, _ = scan_segment(seg)
    if not entries:
        return 0
    start, end = entries[-1][0], entries[-1][1]
    data = bytearray(seg.read_bytes())
    rng = random.Random(seed)
    # corrupt payload bytes only (past the 8-byte frame prefix), so the
    # frame still *parses* and the CRC check is what must reject it
    lo = start + 8
    flips = min(n_flips, end - lo)
    for off in rng.sample(range(lo, end), flips):
        data[off] ^= 0xFF
    seg.write_bytes(bytes(data))
    return flips


def tiny_caps(**overrides) -> StepCapacities:
    """Deliberately tiny capacities: overflow-retry paths on small inputs."""
    base = dict(
        n_removed=4, n_added=4, tau=16, rho=16, pulls=8, fanout=4
    )
    base.update(overrides)
    return StepCapacities(**base)


# ---------------------------------------------------------------------------
# bit-exact broker state capture
# ---------------------------------------------------------------------------

def _canon_rows(rows: np.ndarray) -> np.ndarray:
    """Lex-sorted deduped rows — the canonical form ``from_array`` settles
    on, so a still-raw single-changeset batch and its materialized sorted
    store compare equal (materialization is a fire-time representation
    change, not a state change)."""
    rows = np.asarray(rows, np.int32).reshape(-1, 3)
    return np.unique(rows, axis=0) if rows.size else rows


def broker_state(broker) -> Dict:
    """A broker's observable durable state as comparable host values.

    Captures the unified sequence clock, each subscription (by durable
    jid) with its capacities, consumption frontier, and canonical τ/ρ
    rows, and each pending batch's composed changeset window. Two brokers
    with equal captures are indistinguishable to every future flush —
    this is the bit-identity the recovery contract is stated in.
    """
    subs = {}
    for s in sorted(broker.subs, key=lambda s: s.jid):
        batch = broker._batches.get(s.since)
        if batch is not None:
            d_np, a_np = batch.arrays()
            pending = {
                "first_id": batch.first_id,
                "last_id": batch.last_id,
                "n_changesets": batch.n_changesets,
                "removed": _canon_rows(d_np),
                "added": _canon_rows(a_np),
            }
        else:
            pending = None
        subs[s.jid] = {
            "expr": s.expr,
            "caps": s.caps,
            "since": s.since,
            "tau": to_numpy(s.tau),
            "rho": to_numpy(s.rho),
            "pending": pending,
        }
    return {
        "seq": broker._seq,
        "last_cid": broker._last_cid,
        "jid_next": broker._jid_next,
        "subs": subs,
    }


def assert_state_equal(a: Dict, b: Dict) -> None:
    """Bit-exact comparison of two :func:`broker_state` captures."""
    assert a["seq"] == b["seq"], (a["seq"], b["seq"])
    assert a["last_cid"] == b["last_cid"], (a["last_cid"], b["last_cid"])
    assert a["jid_next"] == b["jid_next"]
    assert sorted(a["subs"]) == sorted(b["subs"]), (
        sorted(a["subs"]), sorted(b["subs"]),
    )
    for jid, sa in a["subs"].items():
        sb = b["subs"][jid]
        assert sa["expr"] == sb["expr"], jid
        assert sa["caps"] == sb["caps"], (jid, sa["caps"], sb["caps"])
        assert sa["since"] == sb["since"], (jid, sa["since"], sb["since"])
        np.testing.assert_array_equal(sa["tau"], sb["tau"], err_msg=f"τ {jid}")
        np.testing.assert_array_equal(sa["rho"], sb["rho"], err_msg=f"ρ {jid}")
        pa, pb = sa["pending"], sb["pending"]
        assert (pa is None) == (pb is None), (jid, pa, pb)
        if pa is not None:
            for key in ("first_id", "last_id", "n_changesets"):
                assert pa[key] == pb[key], (jid, key, pa[key], pb[key])
            np.testing.assert_array_equal(pa["removed"], pb["removed"])
            np.testing.assert_array_equal(pa["added"], pb["added"])
