"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
Mamba-2 backbone (ssm_state=64) + shared attention block every 6 layers.
[arXiv:2411.15242; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=112,
    d_ff=14336,
    vocab=32000,
    ssm_kind="mamba2",
    d_state=64,
    expand=2,
    conv_dim=4,
    ssm_head_dim=64,
    shared_attn_every=6,  # 13 groups of 6 + tail of 3
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=5,  # one group of 2 + tail of 3... (2*2+1)
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        d_head=8,
        d_ff=64,
        vocab=97,
        ssm_kind="mamba2",
        d_state=8,
        expand=2,
        conv_dim=4,
        ssm_head_dim=16,
        ssm_chunk=8,
        shared_attn_every=2,
    )
