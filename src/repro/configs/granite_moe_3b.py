"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    top_k=8,
    d_expert=512,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke",
        family="moe",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_head=8,
        d_ff=16,
        vocab=97,
        n_experts=4,
        top_k=2,
        d_expert=16,
    )
