"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — every 5th layer cross-attends to (stubbed) patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab=128256,
    cross_attn_every=5,  # 20 groups of (4 self + 1 cross)
    n_img_tokens=1601,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke",
        family="vlm",
        n_layers=4,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_head=8,
        d_ff=64,
        vocab=97,
        cross_attn_every=2,
        n_img_tokens=9,
    )
