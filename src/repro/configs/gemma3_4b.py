"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention (window 1024), 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10240,
    vocab=262144,
    attn_pattern="local_global",
    window=1024,
    global_every=6,  # 5 local + 1 global per group
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke",
        family="dense",
        n_layers=8,  # one (5 local + 1 global) group + 2-local tail
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_head=8,
        d_ff=64,
        vocab=97,
        attn_pattern="local_global",
        window=8,
        global_every=6,
    )
