"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared) — trillion-param MoE.
[arXiv:2501.kimi2; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=2048,
    vocab=163840,
    n_experts=384,
    top_k=8,
    d_expert=2048,
    n_shared_experts=1,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-smoke",
        family="moe",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_head=8,
        d_ff=16,
        vocab=97,
        n_experts=8,
        top_k=2,
        d_expert=16,
        n_shared_experts=1,
    )
