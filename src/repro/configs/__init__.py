"""Architecture registry: the 10 assigned archs (full + smoke configs)."""
from importlib import import_module
from typing import Dict

from repro.models.config import ModelConfig

_MODULES = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-medium": "whisper_medium",
    "yi-34b": "yi_34b",
    "gemma3-4b": "gemma3_4b",
    "nemotron-4-15b": "nemotron_4_15b",
    "internlm2-1.8b": "internlm2_1_8b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "zamba2-7b": "zamba2_7b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    return import_module(f"repro.configs.{_MODULES[name]}").CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return import_module(f"repro.configs.{_MODULES[name]}").smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}
