"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — squared-ReLU MLP. [arXiv:2402.16819; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab=256000,
    act="squared_relu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke",
        family="dense",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_head=8,
        d_ff=64,
        vocab=97,
        act="squared_relu",
    )
