"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

llama-arch GQA. [arXiv:2403.04652; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab=64000,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-smoke",
        family="dense",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_head=8,
        d_ff=64,
        vocab=97,
    )
