"""falcon-mamba-7b [ssm]: 64L d_model=4096, attn-free Mamba-1, vocab 65024.

[arXiv:2410.05355; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # attn-free: attention params are never instantiated
    n_kv_heads=1,
    d_head=64,
    d_ff=0,
    vocab=65024,
    ssm_kind="mamba1",
    d_state=16,
    expand=2,
    conv_dim=4,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-smoke",
        family="ssm",
        n_layers=2,
        d_model=32,
        n_heads=1,
        n_kv_heads=1,
        d_head=8,
        d_ff=0,
        vocab=97,
        ssm_kind="mamba1",
        d_state=4,
        expand=2,
        conv_dim=4,
        scan_chunk=8,
    )
