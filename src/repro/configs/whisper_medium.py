"""whisper-medium [audio]: 24L enc + 24L dec, d_model=1024, 16H, vocab 51865.

Encoder-decoder; the conv audio frontend is a STUB — ``input_specs`` provides
precomputed (B, 1500, d_model) frame embeddings. [arXiv:2212.04356]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=51865,
    act="gelu",
    use_layernorm=True,
    enc_seq=1500,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        d_head=8,
        d_ff=64,
        vocab=97,
        act="gelu",
        use_layernorm=True,
        enc_seq=12,
    )
