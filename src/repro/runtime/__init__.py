"""Runtime: fault-tolerant training loop, straggler mitigation, failures."""
from .trainer import SimulatedFailure, Trainer, TrainerConfig

__all__ = ["SimulatedFailure", "Trainer", "TrainerConfig"]
