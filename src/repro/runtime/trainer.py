"""Fault-tolerant training loop.

Production control-plane behaviors, all exercised by tests:
  * checkpoint/restart — atomic snapshots every N steps; on (re)start the
    trainer resumes from the newest complete snapshot, and the data pipeline
    reseeds deterministically from the restored step (no replayed batches).
  * failure injection — ``inject_failure_at`` raises ``SimulatedFailure``
    mid-run; the driver re-creates the Trainer and resumes (tests assert the
    loss trajectory continues rather than restarts).
  * straggler mitigation — per-step wall times feed a rolling median; steps
    slower than ``straggler_factor``x median are logged and counted, and the
    mitigation hook fires (on a real fleet: reassigns that host's data shard
    / excludes it from the next allocation; here: recorded event, pluggable
    callback).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointStore


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    straggler_factor: float = 3.0
    straggler_window: int = 20
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        train_step: Callable,  # (params, opt_state, batch) -> (p, s, metrics)
        init_state: Callable,  # () -> (params, opt_state)
        data: Iterator[Dict[str, np.ndarray]],
        cfg: TrainerConfig,
        shardings: Optional[Dict[str, Any]] = None,
        on_straggler: Optional[Callable[[int, float], None]] = None,
    ):
        self.step_fn = jax.jit(train_step) if not hasattr(train_step, "lower") else train_step
        self.init_state = init_state
        self.data = data
        self.cfg = cfg
        self.store = CheckpointStore(cfg.ckpt_dir)
        self.shardings = shardings
        self.on_straggler = on_straggler
        self.history: List[Dict[str, float]] = []
        self.straggler_events: List[Dict[str, float]] = []

        params, opt_state = init_state()
        self.step = 0
        latest = self.store.latest_step()
        if latest is not None:
            restored, self.step = self.store.restore(
                {"params": params, "opt": opt_state}, shardings=shardings
            )
            params, opt_state = restored["params"], restored["opt"]
        self.params, self.opt_state = params, opt_state

    # ------------------------------------------------------------------
    def run(self, n_steps: int, inject_failure_at: int | None = None):
        times: List[float] = []
        target = self.step + n_steps
        while self.step < target:
            batch = next(self.data)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step += 1
            times.append(dt)

            window = times[-self.cfg.straggler_window :]
            med = float(np.median(window))
            if len(window) >= 5 and dt > self.cfg.straggler_factor * med:
                ev = {"step": self.step, "dt": dt, "median": med}
                self.straggler_events.append(ev)
                if self.on_straggler:
                    self.on_straggler(self.step, dt)

            rec = {"step": self.step, "loss": float(metrics["loss"]), "dt": dt}
            self.history.append(rec)

            if self.step % self.cfg.ckpt_every == 0:
                self.save()
            if inject_failure_at is not None and self.step == inject_failure_at:
                raise SimulatedFailure(f"injected failure at step {self.step}")
        return self.history

    def save(self):
        self.store.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"time": time.time()},
        )
