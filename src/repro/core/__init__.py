"""iRap core: interest-based RDF update propagation, tensorized for TPU.

Public API:
  Dictionary, TripleStore + set algebra      (repro.core.{dictionary,triples})
  InterestExpr / compile_interest            (repro.core.interest)
  make_side_evaluator / TripleIndex          (repro.core.evaluation)
  make_interest_step / IrapEngine            (repro.core.propagation)
  Broker / make_broker_step                  (repro.core.broker)
  ChangesetJournal / DeliveryChannel         (repro.core.{journal,delivery})
"""
from .broker import (
    Broker,
    BrokerStats,
    BrokerSubscription,
    PushPolicy,
    make_broker_step,
    make_cohort_step,
    make_sharded_cohort_step,
)
from .delivery import DeliveryChannel, DeliveryStats
from .dictionary import Dictionary, parse_triples
from .distributed import CohortPlacement
from .journal import ChangesetJournal, JournalRecord
from .interest import (
    CompiledInterest,
    IncrementalPatternBank,
    InterestExpr,
    PatternBank,
    TriplePattern,
    build_pattern_bank,
    compile_interest,
)
from .propagation import (
    ChangesetBatch,
    ChangesetStats,
    EvalOutputs,
    InterestSubscription,
    IrapEngine,
    StepCapacities,
    compose_changesets,
    make_interest_step,
)
from .triples import (
    PAD,
    WILDCARD,
    TripleStore,
    apply_changeset,
    difference,
    empty,
    from_array,
    from_numpy,
    intersection,
    member,
    to_numpy,
    to_set,
    union,
)

__all__ = [
    "Broker",
    "BrokerStats",
    "BrokerSubscription",
    "PushPolicy",
    "make_broker_step",
    "make_cohort_step",
    "make_sharded_cohort_step",
    "ChangesetJournal",
    "JournalRecord",
    "DeliveryChannel",
    "DeliveryStats",
    "CohortPlacement",
    "Dictionary",
    "parse_triples",
    "CompiledInterest",
    "IncrementalPatternBank",
    "InterestExpr",
    "PatternBank",
    "TriplePattern",
    "build_pattern_bank",
    "compile_interest",
    "ChangesetBatch",
    "ChangesetStats",
    "compose_changesets",
    "EvalOutputs",
    "InterestSubscription",
    "IrapEngine",
    "StepCapacities",
    "make_interest_step",
    "PAD",
    "WILDCARD",
    "TripleStore",
    "apply_changeset",
    "difference",
    "empty",
    "from_array",
    "from_numpy",
    "intersection",
    "member",
    "to_numpy",
    "to_set",
    "union",
]
