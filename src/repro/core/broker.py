"""Multi-subscriber interest broker: one fused evaluation pass per changeset.

The paper's headline deployment (§1, §3) is many remote applications each
holding an interest expression ``i_g = <τ, b, op>`` (Definition 7) against
one authoritative source. The seed :class:`~repro.core.propagation.IrapEngine`
serves N subscribers with N independent jitted steps — N full pattern-match
passes over every changeset. This module amortizes the scan:

* All registered interests compile into one :class:`PatternBank`
  (cross-interest dedup of identical triple patterns, static lane maps —
  :func:`repro.core.interest.build_pattern_bank`).
* Each changeset is evaluated by a **single fused jitted step**
  (:func:`make_broker_step`): one chunked ``triple_match`` bank pass over the
  deleted side D (shared verbatim by every subscriber) and one over the
  concatenation of all subscribers' added sides ``I_k = A ∪ ρ_k``
  (Definition 14), then bitset-lane routing (``kernels.ops.lane_bits``)
  hands each subscriber its local pattern bits.
* Subscribers whose interests share the same static plan shape (and
  capacities) form a **cohort** evaluated by one ``jax.vmap`` over the
  pattern values — op count, dispatch, and compile cost scale with the
  number of distinct interest *shapes*, not subscribers.
* Downstream of the bitmask, every subscriber runs the *same* traced
  computation as the single-interest path — the side evaluators of
  :mod:`repro.core.evaluation` (π / π', Definitions 11-12) with precomputed
  bits and traced pattern values (``probe_dyn``), and
  :func:`repro.core.propagation.combine_side_results` for
  Δ(τ) = <r ∪ r', a> (Def 16), Δ(ρ) = <r_i, a_i ∪ r'> (Def 17), and the
  target update Υ (Def 18). Per-subscriber outputs are therefore
  bit-identical to N independent :func:`make_interest_step` runs.

Paper-name ↔ code-name map (Definitions 13-18):

========================  ====================================================
paper                     code
========================  ====================================================
``d(i, D) = <r, r_i, r'>``  ``EvalOutputs.r / .r_i / .r_prime`` (Def 13)
``α(i, A ∪ ρ) = <a, a_i>``  ``EvalOutputs.a / .a_i``            (Def 14)
``Δ(τ)``                    applied to ``BrokerSubscription.tau`` (Def 16)
``Δ(ρ)``                    applied to ``BrokerSubscription.rho`` (Def 17)
``Υ``                       ``combine_side_results``              (Def 18)
========================  ====================================================

The host-side :class:`Broker` mirrors the iRap architecture's Interest
Manager / Changeset Manager / Evaluator split: subscriptions register (and
invalidate the fused step), changesets stream through
:meth:`Broker.process_changeset`, and per-subscriber overflow doubles only
that subscriber's capacities before a re-jit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .dictionary import Dictionary
from .evaluation import build_index, make_side_evaluator
from .interest import (
    CompiledInterest,
    InterestExpr,
    PatternBank,
    build_pattern_bank,
    compile_interest,
)
from .propagation import EvalOutputs, StepCapacities, combine_side_results
from .triples import TripleStore, empty, from_array, union


def _plan_shape_key(plan: CompiledInterest):
    """Static evaluation structure of a plan — everything the traced
    evaluator specializes on except the pattern *values* (which slots are
    constant matters; what constant they hold does not)."""
    const_mask = tuple(
        tuple(int(x) >= 0 for x in row) for row in plan.patterns
    )
    return (
        plan.n_bgp,
        plan.n_ogp,
        plan.kinds,
        plan.anchor_slot,
        plan.child_slot,
        plan.child_var,
        plan.eq_pairs,
        plan.n_children,
        const_mask,
    )


@dataclasses.dataclass(frozen=True)
class _Cohort:
    """Subscribers sharing plan shape + capacities: evaluated via one vmap."""

    indices: Tuple[int, ...]
    plan: CompiledInterest  # representative — static structure only
    caps: StepCapacities
    id_capacity: int


def make_broker_step(
    bank: PatternBank,
    plans: Sequence[CompiledInterest],
    caps_list: Sequence[StepCapacities],
    id_capacities: Sequence[int],
    matcher: Optional[Callable] = None,
) -> Callable:
    """Jitted fused step: (D, A, (τ_k,), (ρ_k,)) -> ((τ'_k,), (ρ'_k,), (out_k,)).

    One chunked bank bitmask pass over D shared by everyone, one per cohort
    over the stacked ``I_k`` sets, then **vmapped** side evaluation +
    Δ/Υ combine per cohort: subscribers whose interests share the same
    static shape (pattern kinds/slots/const-masks, Definition 7 structure)
    and capacities are batched into a single traced computation, so the
    op count — and with it dispatch and compile cost — is per *cohort*, not
    per subscriber. Heterogeneous subscribers degrade gracefully to
    size-1 cohorts.
    """
    n_subs = len(plans)
    assert n_subs == len(caps_list) == len(id_capacities) == len(bank.lanes)
    bank_dev = jnp.asarray(bank.patterns)

    # group subscribers into shape-homogeneous cohorts (stable order)
    groups: dict = {}
    for k, (plan, caps, id_cap) in enumerate(
        zip(plans, caps_list, id_capacities)
    ):
        key = (_plan_shape_key(plan), caps, id_cap)
        groups.setdefault(key, []).append(k)
    cohorts = [
        _Cohort(
            indices=tuple(idxs),
            plan=plans[idxs[0]],
            caps=caps_list[idxs[0]],
            id_capacity=id_capacities[idxs[0]],
        )
        for idxs in groups.values()
    ]

    cohort_evals = []  # (eval_d, eval_a, pats (Nc, nt, 3), lanes (Nc, nt))
    for c in cohorts:
        eval_kw = dict(
            id_capacity=c.id_capacity,
            fanout=c.caps.fanout,
            pull_capacity=c.caps.pulls,
            matcher=matcher,
            dedup_candidates=c.caps.dedup_candidates,
            dynamic_patterns=True,
        )
        eval_d = make_side_evaluator(
            c.plan, out_capacity=c.caps.n_removed, **eval_kw
        )
        eval_a = make_side_evaluator(c.plan, out_capacity=c.caps.n_i, **eval_kw)
        pats = jnp.asarray(
            np.stack([plans[k].patterns for k in c.indices]), jnp.int32
        )
        lanes = jnp.asarray(
            np.array([bank.lanes[k] for k in c.indices], np.int32)
        )
        cohort_evals.append((eval_d, eval_a, pats, lanes))

    def bank_words(spo: jax.Array) -> jax.Array:
        return kops.pattern_bitmask_words(spo, bank_dev, matcher=matcher)

    def tree_stack(trees):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    def tree_index(tree, i):
        return jax.tree.map(lambda x: x[i], tree)

    @jax.jit
    def step(
        d_set: TripleStore,
        a_set: TripleStore,
        taus: Tuple[TripleStore, ...],
        rhos: Tuple[TripleStore, ...],
    ):
        # fused pass 1: deleted side, shared by every subscriber
        d_words = bank_words(d_set.spo)

        tau1s = [None] * n_subs
        rho1s = [None] * n_subs
        outs = [None] * n_subs
        for c, (eval_d, eval_a, pats, lanes) in zip(cohorts, cohort_evals):
            nc = len(c.indices)
            caps = c.caps
            taus_c = tree_stack([taus[k] for k in c.indices])
            rhos_c = tree_stack([rhos[k] for k in c.indices])

            # I_k = A ∪ ρ_k (Def 14); fused pass 2 over the stacked cohort
            i_sets, ovf_i = jax.vmap(lambda r: union(a_set, r, caps.n_i))(
                rhos_c
            )
            i_cap = i_sets.spo.shape[1]
            i_words = bank_words(i_sets.spo.reshape(-1, 3)).reshape(
                nc, i_cap, bank.n_words
            )

            # bitset-lane routing: bank words -> per-member local bits
            d_bits = kops.lane_bits_batched(
                jnp.broadcast_to(d_words[None], (nc,) + d_words.shape), lanes
            )
            a_bits = kops.lane_bits_batched(i_words, lanes)

            tgts = jax.vmap(build_index)(taus_c)
            d_res = jax.vmap(
                lambda tgt, bits, p: eval_d(d_set, tgt, bits, p)
            )(tgts, d_bits, pats)
            a_res = jax.vmap(
                lambda i_set, tgt, bits, p: eval_a(i_set, tgt, bits, p)
            )(i_sets, tgts, a_bits, pats)
            tau1_c, rho1_c, out_c = jax.vmap(
                lambda dr, ar, t, r, o: combine_side_results(
                    dr, ar, t, r, caps, o
                )
            )(d_res, a_res, taus_c, rhos_c, ovf_i)

            for pos, k in enumerate(c.indices):
                tau1s[k] = tree_index(tau1_c, pos)
                rho1s[k] = tree_index(rho1_c, pos)
                outs[k] = tree_index(out_c, pos)
        return tuple(tau1s), tuple(rho1s), tuple(outs)

    return step


class BrokerSubscription:
    """One registered interest inside the broker: plan, caps, τ, ρ."""

    def __init__(
        self, expr: InterestExpr, dictionary: Dictionary, caps: StepCapacities
    ):
        self.expr = expr
        self.dictionary = dictionary
        self.caps = caps
        self.plan = compile_interest(expr, dictionary)
        self.id_capacity = dictionary.id_capacity * caps.id_headroom
        self.tau = empty(caps.tau)
        self.rho = empty(caps.rho)

    def recompile(self, caps: StepCapacities | None = None) -> None:
        """Refresh plan/capacities after dictionary or capacity growth."""
        if caps is not None:
            self.caps = caps
        self.plan = compile_interest(self.expr, self.dictionary)
        self.id_capacity = self.dictionary.id_capacity * self.caps.id_headroom
        self.tau, _ = union(empty(self.caps.tau), self.tau, self.caps.tau)
        self.rho, _ = union(empty(self.caps.rho), self.rho, self.caps.rho)

    def init_target(self, triples: np.ndarray) -> bool:
        """Load the initial RDFSlice-style subset into τ. True if caps grew."""
        grew = False
        while True:
            store, overflow = from_array(
                jnp.asarray(triples, jnp.int32), self.caps.tau
            )
            if not bool(overflow):
                self.tau = store
                return grew
            self.recompile(self.caps.doubled())
            grew = True


@dataclasses.dataclass
class BrokerStats:
    """Per-changeset accounting for the fused pass (all subscribers)."""

    changeset_id: int
    n_subscribers: int
    n_lanes: int  # deduplicated bank size
    n_lanes_raw: int  # sum of per-interest pattern counts
    total_removed: int
    total_added: int
    interesting_removed: int  # Σ_k |r_k|
    interesting_added: int  # Σ_k |a_k|
    elapsed_s: float


class Broker:
    """Host orchestrator batching all registered interests into one pass.

    Drop-in counterpart of :class:`~repro.core.propagation.IrapEngine` for
    the many-subscriber regime: ``subscribe`` replaces ``register_interest``
    and ``process_changeset`` evaluates every subscription with a single
    fused jitted step instead of one step per subscription.
    """

    def __init__(
        self,
        dictionary: Dictionary | None = None,
        matcher: Optional[Callable] = None,
    ):
        self.dictionary = dictionary if dictionary is not None else Dictionary()
        self.matcher = matcher
        self.subs: List[BrokerSubscription] = []
        self.stats: List[BrokerStats] = []
        self.bank: PatternBank | None = None
        self._step: Callable | None = None
        self._counter = 0
        self.rejit_count = 0  # fused-step (re)builds, for tests/benchmarks

    # -- interest manager ---------------------------------------------------

    def subscribe(
        self,
        expr: InterestExpr,
        caps: StepCapacities = StepCapacities(),
        initial_target: np.ndarray | None = None,
    ) -> BrokerSubscription:
        sub = BrokerSubscription(expr, self.dictionary, caps)
        if initial_target is not None and initial_target.size:
            sub.init_target(initial_target)
        self.subs.append(sub)
        self._step = None  # pattern bank changed: rebuild on next changeset
        return sub

    def unsubscribe(self, sub: BrokerSubscription) -> None:
        self.subs.remove(sub)
        self._step = None

    # -- fused-step lifecycle -----------------------------------------------

    def _rebuild(self) -> None:
        for sub in self.subs:
            sub.recompile()
        self.bank = build_pattern_bank([s.plan for s in self.subs])
        self._step = make_broker_step(
            self.bank,
            [s.plan for s in self.subs],
            [s.caps for s in self.subs],
            [s.id_capacity for s in self.subs],
            matcher=self.matcher,
        )
        self.rejit_count += 1

    def _ensure_step(self) -> None:
        if self._step is None:
            self._rebuild()
            return
        if any(
            self.dictionary.id_capacity > s.id_capacity for s in self.subs
        ):
            self._rebuild()

    # -- changeset manager + evaluator --------------------------------------

    def process_changeset(
        self, removed: np.ndarray, added: np.ndarray
    ) -> List[EvalOutputs]:
        """Evaluate one changeset for every subscriber in one fused pass.

        Returns one :class:`EvalOutputs` per subscriber, in subscription
        order — each bit-identical to what the seed per-interest engine
        would produce for that subscriber alone.
        """
        self._counter += 1
        if not self.subs:
            return []
        t0 = time.perf_counter()
        while True:
            # host-side capacity guard (per subscriber, like the seed engine)
            for sub in self.subs:
                while (
                    removed.shape[0] > sub.caps.n_removed
                    or added.shape[0] > sub.caps.n_added
                ):
                    sub.recompile(sub.caps.doubled())
                    self._step = None
            self._ensure_step()

            d_cap = max(s.caps.n_removed for s in self.subs)
            a_cap = max(s.caps.n_added for s in self.subs)
            d_store, _ = from_array(jnp.asarray(removed, jnp.int32), d_cap)
            a_store, _ = from_array(jnp.asarray(added, jnp.int32), a_cap)
            tau1s, rho1s, outs = self._step(
                d_store,
                a_store,
                tuple(s.tau for s in self.subs),
                tuple(s.rho for s in self.subs),
            )
            overflowed = [
                k for k in range(len(self.subs)) if bool(outs[k].overflow)
            ]
            if overflowed:
                # grow only the subscribers that overflowed, then re-jit
                for k in overflowed:
                    self.subs[k].recompile(self.subs[k].caps.doubled())
                self._step = None
                continue
            for k, sub in enumerate(self.subs):
                sub.tau, sub.rho = tau1s[k], rho1s[k]
            jax.block_until_ready(self.subs[-1].tau.spo)
            elapsed = time.perf_counter() - t0
            self.stats.append(
                BrokerStats(
                    changeset_id=self._counter,
                    n_subscribers=len(self.subs),
                    n_lanes=self.bank.n_lanes,
                    n_lanes_raw=sum(s.plan.n_total for s in self.subs),
                    total_removed=int(removed.shape[0]),
                    total_added=int(added.shape[0]),
                    interesting_removed=sum(int(o.r.n) for o in outs),
                    interesting_added=sum(int(o.a.n) for o in outs),
                    elapsed_s=elapsed,
                )
            )
            return list(outs)
