"""Multi-subscriber interest broker: cohort-cached fused evaluation passes.

The paper's headline deployment (§1, §3) is many long-lived remote
applications each holding an interest expression ``i_g = <τ, b, op>``
(Definition 7) against one continuously-evolving source. PR 1 amortized the
per-changeset scan across subscribers with a single fused jitted step; this
module additionally amortizes the *lifecycle*: subscribers come and go, and
none of that churn may recompile work that belongs to other subscribers.

The broker is four layers, plus a distribution layer over them:

1. **Cohort executable cache.** Subscribers whose interests share the same
   static plan shape (pattern kinds/slots/const-masks, Definition 7
   structure) and capacities form a cohort evaluated by one ``jax.vmap``
   over the pattern *values* (:func:`make_cohort_step`). Each cohort's step
   is compiled separately and cached under ``(plan-shape key, caps,
   id-capacity, padded cohort size, padded target count, padded bank
   words)``. Cohort membership is padded to power-of-two sizes with masked
   dummy lanes (``kernels.ops.lane_bits_batched(active=...)`` zeroes their
   bits, so they contribute nothing and cost no extra recompiles), and every
   dynamic quantity — pattern values, lane maps, the bank array, the member
   mask — is a *traced input*, so subscribing, unsubscribing, or growing one
   subscriber (re)compiles at most its own cohort; every other cohort
   reuses its cached executable.

2. **Incremental pattern bank.** All registered interests dedup into one
   :class:`~repro.core.interest.IncrementalPatternBank`: subscribing extends
   lanes without renumbering existing ones, unsubscribing tombstones lanes
   (reused by later subscriptions) until compaction, and the device bank
   array is padded to power-of-two lane counts — so bank churn neither
   invalidates unrelated cohorts' lane maps nor changes executable input
   shapes. Per changeset there is one chunked bank bitmask pass over the
   deleted side D shared by every cohort, and one per cohort over the
   stacked ``I_k = A ∪ ρ_k`` sets (Definition 14); bitset-lane routing hands
   each subscriber its local pattern bits.

3. **Interest-subsumption lattice + subscriber fanout** (default,
   ``Broker(subsume_interests=False)`` preserves the per-subscriber PR 5
   path as the baseline). The paper's deployment is many consumers holding
   *overlapping* interests over one stream, so distinct interests — not
   subscribers — are the unit of evaluation cost (cf. Fedra's
   containment-driven source selection and Knuth & Hartig's
   distinct-queries scheduling):

   * **canonical lane groups.** Every ``subscribe()`` canonicalizes its
     expression (:func:`repro.core.interest.canonicalize_expr`: skeleton
     pattern sort + bijective variable renaming), so expressions that
     differ only in pattern order / variable names land on identical
     compiled plans and bank lanes. A new subscription whose canonical
     key, capacities, policy, frontier, and τ/ρ state provably match an
     existing lineage auto-joins it (the previously opt-in
     ``share_target`` detection, now automatic for the exact-duplicate
     case); members of one lineage occupy ONE cohort slot per fire — the
     lane result is computed once and **fanned out host-side** to every
     member's output, with per-subscriber τ/ρ applied only at commit, so
     delivery is O(1) executable work per distinct interest
     (``BrokerStats.distinct_interests`` vs ``fanout_copies``).
   * **containment DAG.** Bank rows are deduplicated pattern-wise and a
     row whose pattern is *strictly contained* by an existing row's (a
     constant where the parent has a variable) becomes a **virtual lane**
     (:class:`~repro.core.interest.SubsumptionBank`): it occupies no bank
     width in the deleted-side words pass — its words are the parent
     lane's already-emitted words ANDed with the cheap residual-constant
     compare (:func:`repro.kernels.ops.lane_refine`), concatenated after
     the real planes so lane routing is oblivious to the distinction.
     The added-side fused pass matches virtual rows as materialized
     patterns in the extended bank (refining the fused kernel is a
     ROADMAP follow-on).

4. **Push scheduler — device-resident, delta-chained frontiers.** Each
   subscription carries a :class:`PushPolicy` (every-k-changesets, priority
   lane, or max-staleness, cf. the SPARQL refresh-scheduling literature).
   The host orchestrator accumulates pending changesets as composed batches
   (:func:`repro.core.propagation.compose_changesets` — Definition 6
   algebra over the device triple-set ops — one batch per consumption
   frontier), and a subscriber's cohort is routed through the fused pass
   only when its policy fires; :meth:`Broker.flush` drains the rest (a
   flush with nothing pending, and a fired frontier whose composed batch
   is empty, return without touching statics or executables at all). The
   deferred path stays on device end-to-end: a fire consumes the batch's
   already-lex-sorted device stores (:meth:`~repro.core.propagation
   .ChangesetBatch.device_stores`), re-homing via
   :func:`repro.core.triples.rehome` (pad/slice, never re-sort or
   transfer) when padding shapes change, and when several frontiers fire in
   one call their same-shape cohort invocations stack into ONE batched
   executable call (the frontier is one more padded, masked axis folded
   into the cohort's member dimension — see :func:`make_cohort_step`).

   Fired frontiers *overlap* — every batch composes a suffix of the same
   stream — so the multi-frontier deleted-side pass is **delta-encoded**
   rather than stacked: the flush builds a
   :class:`~repro.core.propagation.FrontierChain` (the lex-sorted
   distinct-row union of every fired D side plus per-frontier int32
   membership bitmaps, probed — not assumed — with an exact containment
   check) and ONE segmented bank pass
   (:func:`repro.kernels.ops.pattern_bitmask_words_segmented`) matches
   each distinct changeset row once, composing each frontier's words by
   membership masking. Cohort members then share the single union store —
   their ``f_map`` slot selects masked words instead of gathering
   duplicated per-frontier stores — and rows outside a member's frontier
   carry zero bits, which the evaluator's zero-bits discipline turns into
   "no candidates, no signatures, no outputs", keeping every output
   bit-identical to the stacked evaluation while the matched-row volume
   drops from ~F× the union to ~1× (observable as
   ``BrokerStats.rows_matched`` vs ``rows_distinct``).
   ``Broker(delta_frontiers=False)`` preserves the stacked per-frontier
   pass as the escape hatch / benchmark baseline. Subscribers attached to
   one target dataset replica (``subscribe(..., share_target=True)``)
   share a single ``build_index(τ)`` inside the cohort step.

5. **Device-sharded cohort routing.** Cohorts are independently compiled,
   independently schedulable units, which makes them the natural unit of
   *distribution*: with ``Broker(mesh=...)`` a
   :class:`~repro.core.distributed.CohortPlacement` policy places each
   cohort on a mesh device (round-robin, load-balanced by padded member
   count, or pinned) and the frontier pass dispatches its cohort calls
   grouped by device — executables, statics, the padded bank copy, and
   every member's τ/ρ state stay resident per device, so steady-state
   fires move only the frontier's changeset slices and the asynchronously
   dispatched cohorts run concurrently across the mesh. With
   ``shard_cohorts=True`` each cohort pass instead runs *inside* shard_map
   over the whole mesh (:func:`make_sharded_cohort_step`): τ replicas
   hash-partition across the shards (cached per (subscription, τ-version,
   capacity), so churn never re-partitions untouched replicas), the bank
   match passes block-split and block-gather-stitched, and candidate probes
   route to their owner shard via the batched all_to_all probe. Both modes
   are bit-identical to the single-device broker by construction; the
   per-frontier composed batches remain the delivery windows — the natural
   cross-host boundary.

6. **Durability + delivery robustness** (both opt-in; a broker without a
   journal or channel behaves exactly as before, on the same unified
   sequence clock). Attaching a :class:`~repro.core.journal.ChangesetJournal`
   (``Broker(journal=...)`` or ``broker.journal = ...``) write-ahead-logs
   every state-changing event on one monotonic sequence: ``subscribe`` /
   ``unsubscribe`` records carry the call's arguments, ``ingest`` records
   carry the raw changeset arrays (appended *before* the batches extend),
   and a ``fire`` record carries the acked ``{subscriber: new frontier}``
   advances — appended after delivery but *before* the in-memory commit,
   so the journal's durable prefix is always a consistent boundary.
   :meth:`Broker.snapshot` checkpoints full subscriber state (τ/ρ valid
   rows, caps, policy, frontier) through the
   :class:`~repro.checkpoint.store.CheckpointStore` atomic tmp-dir+rename
   discipline keyed by journal seq, and :meth:`Broker.recover` rebuilds a
   bit-identical broker by snapshot-plus-tail-replay (replayed ingests
   rebuild composed batches; replayed fires re-evaluate exactly the
   recorded subscribers with delivery suppressed).

   **The durability/exactly-once contract.** Recovery gives at-least-once
   fire semantics: a crash between delivery and the ``fire`` record means
   the frontier never durably advanced, so the next fire re-delivers —
   but always as the *composed* window ``C[f..j]`` re-extended to
   ``C[f..j']``. Definition 6 composition makes that idempotent for the
   receiver: for set-semantic changesets, ``apply(apply(τ, X), X∘Y) ==
   apply(apply(τ, X), Y)`` — the composed delta's D side re-deletes rows
   already gone and its A side re-adds rows already present — so a replica
   that applies every delivered composed window converges to exactly-once
   *state* regardless of redelivery. This is why the journal only needs
   ingest WAL + acked-frontier records, never delivered payloads.

   A :class:`~repro.core.delivery.DeliveryChannel` (``Broker(channel=...)``)
   adds the failure-handling tier at the same commit point: per-subscriber
   retry with exponential backoff + jitter + timeout, a bounded in-flight
   retry queue that backpressures :meth:`process_changeset`, and poison
   quarantine — a subscriber failing N consecutive deliveries stops firing
   (its frontier pins, its batch keeps composing) instead of stalling the
   broker. Delivery happens before commit, so a failed delivery needs no
   rollback: the subscriber is simply not committed. Channel state (retry
   counts, quarantine) is deliberately *not* durable — after recovery every
   subscriber starts unpinned and re-earns its quarantine. Finally, the
   capacity-overflow retry loop gains a bounded ceiling
   (``max_fire_retries``): past it, the affected subscribers are evaluated
   through the per-interest seed path (bit-identical by the oracle
   discipline, just slower) and ``BrokerStats.degraded_fires`` records the
   degradation instead of the fire doubling capacities without limit.

Downstream of the bitmask every subscriber runs the *same* traced
computation as the single-interest path — the side evaluators of
:mod:`repro.core.evaluation` (π / π', Definitions 11-12) with precomputed
bits and traced pattern values (``probe_dyn``), and
:func:`repro.core.propagation.combine_side_results` for Δ(τ), Δ(ρ), Υ
(Definitions 16-18) — so per-subscriber outputs stay bit-identical to N
independent :func:`~repro.core.propagation.make_interest_step` runs.

Paper-name ↔ code-name map (Definitions 13-18):

========================  ====================================================
paper                     code
========================  ====================================================
``d(i, D) = <r, r_i, r'>``  ``EvalOutputs.r / .r_i / .r_prime`` (Def 13)
``α(i, A ∪ ρ) = <a, a_i>``  ``EvalOutputs.a / .a_i``            (Def 14)
``Δ(τ)``                    applied to ``BrokerSubscription.tau`` (Def 16)
``Δ(ρ)``                    applied to ``BrokerSubscription.rho`` (Def 17)
``Υ``                       ``combine_side_results``              (Def 18)
========================  ====================================================

The host-side :class:`Broker` mirrors the iRap architecture's Interest
Manager / Changeset Manager / Evaluator split, with compile/rebuild time
accounted separately from evaluation time (``BrokerStats.rejit_s``).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..kernels import ops as kops
from .dictionary import Dictionary
from .distributed import (
    CohortPlacement,
    make_or_reduce,
    make_routed_probe_batched,
    prepare_target_shards,
    shard_map_compat,
)
from .evaluation import (
    SideResult,
    TripleIndex,
    build_index,
    make_side_evaluator,
    tree_gather,
    tree_index,
    tree_stack,
)
from .interest import (
    CompiledInterest,
    IncrementalPatternBank,
    InterestExpr,
    PatternBank,
    SubsumptionBank,
    canonicalize_expr,
    compile_interest,
    next_pow2,
)
from .journal import ChangesetJournal
from .propagation import (
    ChangesetBatch,
    EvalOutputs,
    StepCapacities,
    build_frontier_chain,
    combine_side_results,
    make_interest_step,
)
from .triples import (
    PAD,
    TripleStore,
    empty,
    from_array,
    rehome,
    to_numpy,
    union,
)


def _plan_shape_key(plan: CompiledInterest):
    """Static evaluation structure of a plan — everything the traced
    evaluator specializes on except the pattern *values* (which slots are
    constant matters; what constant they hold does not)."""
    const_mask = tuple(
        tuple(int(x) >= 0 for x in row) for row in plan.patterns
    )
    return (
        plan.n_bgp,
        plan.n_ogp,
        plan.kinds,
        plan.anchor_slot,
        plan.child_slot,
        plan.child_var,
        plan.eq_pairs,
        plan.n_children,
        const_mask,
    )


# ---------------------------------------------------------------------------
# durability: journal/snapshot (de)serialization of subscription arguments
# ---------------------------------------------------------------------------

def _expr_to_json(expr: InterestExpr) -> dict:
    return {
        "source": expr.source,
        "target": expr.target,
        "bgp": [list(p.slots()) for p in expr.bgp],
        "ogp": [list(p.slots()) for p in expr.ogp],
    }


def _expr_from_json(d: dict) -> InterestExpr:
    return InterestExpr.parse(
        d["source"], d["target"],
        bgp=[tuple(p) for p in d["bgp"]],
        ogp=[tuple(p) for p in d.get("ogp", [])],
    )


def _caps_to_json(caps: StepCapacities) -> dict:
    return dataclasses.asdict(caps)


def _caps_from_json(d: dict) -> StepCapacities:
    return StepCapacities(**d)


def _policy_to_json(policy: "PushPolicy | None") -> dict | None:
    return None if policy is None else dataclasses.asdict(policy)


def _policy_from_json(d: dict | None) -> "PushPolicy | None":
    return None if d is None else PushPolicy(**d)


# ---------------------------------------------------------------------------
# layer 4: push scheduling policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PushPolicy:
    """When a subscriber's pending batch is routed through the fused pass.

    Real consumers want per-subscriber cadences, not lock-step evaluation at
    every changeset (cf. the SPARQL refresh-scheduling literature): a slow
    replica can absorb k changesets per push, a dashboard wants every update
    immediately, a mirror only bounds staleness.

    ``every_k``           fire once k changesets are pending (1 = eager;
                          None disables count-based firing).
    ``max_staleness_s``   fire once this many seconds have passed since the
                          subscriber's last push (None disables).
    ``priority``          priority lane: fire at every changeset and run
                          before non-priority work in the pass order.

    A subscriber with nothing pending never fires; :meth:`Broker.flush`
    drains pending batches regardless of policy.
    """

    every_k: Optional[int] = 1
    max_staleness_s: Optional[float] = None
    priority: bool = False

    @staticmethod
    def every(k: int) -> "PushPolicy":
        """Batch k changesets between pushes (slow-consumer cadence)."""
        return PushPolicy(every_k=k)

    @staticmethod
    def priority_lane() -> "PushPolicy":
        """Evaluate at every changeset, ahead of non-priority subscribers."""
        return PushPolicy(every_k=1, priority=True)

    @staticmethod
    def max_staleness(seconds: float) -> "PushPolicy":
        """Fire only when the replica's staleness bound is reached."""
        return PushPolicy(every_k=None, max_staleness_s=seconds)

    def fires(self, pending: int, staleness_s: float) -> bool:
        if pending <= 0:
            return False
        if self.priority:
            return True
        if self.every_k is not None and pending >= self.every_k:
            return True
        return (
            self.max_staleness_s is not None
            and staleness_s >= self.max_staleness_s
        )


# ---------------------------------------------------------------------------
# layer 1: per-cohort jitted step
# ---------------------------------------------------------------------------

def make_cohort_step(
    plan: CompiledInterest,
    caps: StepCapacities,
    id_capacity: int,
    matcher: Optional[Callable] = None,
    delta: bool = False,
) -> Callable:
    """Build the jitted fused step for ONE shape-homogeneous cohort,
    spanning every deferred frontier that fires in the same call.

    ``plan`` supplies only static structure (kinds, slots, const masks); the
    pattern *values*, lane maps, bank array, target stores, frontier
    changesets, and member mask are traced inputs, so one compiled
    executable serves any cohort of this shape — across subscription churn,
    bank growth, re-subscription, and any assignment of members to
    frontiers.

    Signature (``Nc`` = padded member count across all frontiers, ``Nu`` =
    padded unique-target count, ``Fp`` = padded frontier count, ``W`` =
    padded bank words)::

        step(d_sets,           # Fp-tuple of TripleStore — deleted side per
                               #   frontier (padding slots: empty stores)
             d_words,          # Fp-tuple of uint32[|D|, W] bank bitsets
             a_sets,           # Fp-tuple of TripleStore — added side
             bank_dev,         # int32[32 W, 3] padded pattern bank
             uniq_taus,        # Nu-tuple of TripleStore — unique replicas
             f_map,            # int32[Nc] member -> frontier slot
             tgt_map,          # int32[Nc] member -> unique replica slot
             rhos,             # Nc-tuple of TripleStore
             pats,             # int32[Nc, nt, 3] pattern values per member
             lanes,            # int32[Nc, nt] bank lane per local pattern
             active,           # bool[Nc] member mask (False = padding lane)
        ) -> (tau1s, rho1s, outs)   # Nc-tuples, per member

    The frontier dimension is folded into the member axis rather than a
    nested batch: every member gathers its own frontier's (D, A, D-words)
    slice via ``f_map`` and the whole cohort — across however many deferred
    frontiers fired together — runs as ONE vmapped executable call. A
    single-frontier fire is simply ``Fp == 1`` with an all-zero ``f_map``,
    so the eager path and the stacked flush path share executables of the
    same shape family (cached separately per ``Fp``).

    Member stores go in and come out as *tuples*: stacking for the vmap and
    per-member unstacking happen inside the traced step, so the host pays
    one executable call per cohort instead of O(members) eager stack/slice
    dispatches per changeset. The added side routes through the fused
    match+route kernel (:func:`repro.kernels.ops.pattern_lane_bits_batched`)
    — one pass over each member's ``I_k`` rows regardless of bank width.

    ``build_index(τ)`` runs once per *unique* target replica and is fanned
    out to members via ``tgt_map`` — subscribers attached to one target
    dataset share the index build. Inactive (padding) members contribute
    zero pattern bits and empty outputs.

    ``delta=True`` builds the **delta-chain** variant: the per-frontier
    ``d_sets`` tuple is replaced by ONE shared union store (the distinct D
    rows across every fired frontier,
    :class:`~repro.core.propagation.FrontierChain`), and ``d_words``
    carries the per-frontier *membership-masked* words over the union rows
    (one segmented bank pass upstream instead of one stacked pass per
    frontier). Every member evaluates the same union store; its ``f_map``
    slot selects its frontier's masked words, and rows outside that
    frontier carry zero bits — which the evaluator turns into "no
    candidates, no signature scatters, no outputs", exactly the sharded
    path's ``row_mask`` discipline — so outputs stay bit-identical to the
    stacked per-frontier evaluation while each distinct changeset row is
    matched (and its store gathered) once instead of once per frontier::

        step(d_union,      # TripleStore — union D rows, shared by members
             d_words,      # Fp-tuple of uint32[|U|, W] masked union words
             a_sets, bank_dev, uniq_taus, f_map, tgt_map, rhos,
             pats, lanes, active) -> (tau1s, rho1s, outs)
    """
    eval_kw = dict(
        id_capacity=id_capacity,
        fanout=caps.fanout,
        pull_capacity=caps.pulls,
        matcher=matcher,
        dedup_candidates=caps.dedup_candidates,
        dynamic_patterns=True,
    )
    eval_d = make_side_evaluator(plan, out_capacity=caps.n_removed, **eval_kw)
    eval_a = make_side_evaluator(plan, out_capacity=caps.n_i, **eval_kw)

    if delta:

        @jax.jit
        def step_delta(
            d_union: TripleStore,
            d_words: Tuple[jax.Array, ...],
            a_sets: Tuple[TripleStore, ...],
            bank_dev: jax.Array,
            uniq_taus: Tuple[TripleStore, ...],
            f_map: jax.Array,
            tgt_map: jax.Array,
            rhos: Tuple[TripleStore, ...],
            pats: jax.Array,
            lanes: jax.Array,
            active: jax.Array,
        ):
            nc = lanes.shape[0]
            rhos_s = tree_stack(list(rhos))
            uniq_s = tree_stack(list(uniq_taus))
            a_stack = tree_stack(list(a_sets))
            w_stack = jnp.stack(list(d_words))

            a_mem = tree_gather(a_stack, f_map)
            i_sets, ovf_i = jax.vmap(lambda a, r: union(a, r, caps.n_i))(
                a_mem, rhos_s
            )
            a_bits = kops.pattern_lane_bits_batched(
                i_sets.spo, bank_dev, lanes, active, matcher=matcher
            )
            # each member reads its frontier's membership-masked union
            # words; the union STORE itself is one closed-over constant —
            # no per-member store gather, no stacked per-frontier copies
            d_bits = kops.lane_bits_batched(
                jnp.take(w_stack, f_map, axis=0), lanes, active=active
            )

            tgts_u = jax.vmap(build_index)(uniq_s)
            tgts = tree_gather(tgts_u, tgt_map)
            taus = tree_gather(uniq_s, tgt_map)

            d_res = jax.vmap(
                lambda tgt, bits, p: eval_d(d_union, tgt, bits, p)
            )(tgts, d_bits, pats)
            a_res = jax.vmap(
                lambda i_set, tgt, bits, p: eval_a(i_set, tgt, bits, p)
            )(i_sets, tgts, a_bits, pats)
            tau1, rho1, out = jax.vmap(
                lambda dr, ar, t, r, o: combine_side_results(
                    dr, ar, t, r, caps, o
                )
            )(d_res, a_res, taus, rhos_s, ovf_i)
            return (
                tuple(tree_index(tau1, i) for i in range(nc)),
                tuple(tree_index(rho1, i) for i in range(nc)),
                tuple(tree_index(out, i) for i in range(nc)),
            )

        return step_delta

    @jax.jit
    def step(
        d_sets: Tuple[TripleStore, ...],
        d_words: Tuple[jax.Array, ...],
        a_sets: Tuple[TripleStore, ...],
        bank_dev: jax.Array,
        uniq_taus: Tuple[TripleStore, ...],
        f_map: jax.Array,
        tgt_map: jax.Array,
        rhos: Tuple[TripleStore, ...],
        pats: jax.Array,
        lanes: jax.Array,
        active: jax.Array,
    ):
        nc = lanes.shape[0]
        rhos_s = tree_stack(list(rhos))
        uniq_s = tree_stack(list(uniq_taus))
        d_stack = tree_stack(list(d_sets))
        a_stack = tree_stack(list(a_sets))
        w_stack = jnp.stack(list(d_words))

        # every member reads its own frontier's composed changeset
        d_mem = tree_gather(d_stack, f_map)
        a_mem = tree_gather(a_stack, f_map)
        # I_k = A_f(k) ∪ ρ_k (Def 14)
        i_sets, ovf_i = jax.vmap(lambda a, r: union(a, r, caps.n_i))(
            a_mem, rhos_s
        )
        # fused bank match + bitset-lane routing + member mask in one pass
        # (padding members masked to zero so they see no candidates at all)
        a_bits = kops.pattern_lane_bits_batched(
            i_sets.spo, bank_dev, lanes, active, matcher=matcher
        )
        d_bits = kops.lane_bits_batched(
            jnp.take(w_stack, f_map, axis=0), lanes, active=active
        )

        # one build_index(τ) per unique target replica, gathered per member
        tgts_u = jax.vmap(build_index)(uniq_s)
        tgts = tree_gather(tgts_u, tgt_map)
        taus = tree_gather(uniq_s, tgt_map)

        d_res = jax.vmap(
            lambda d_set, tgt, bits, p: eval_d(d_set, tgt, bits, p)
        )(d_mem, tgts, d_bits, pats)
        a_res = jax.vmap(
            lambda i_set, tgt, bits, p: eval_a(i_set, tgt, bits, p)
        )(i_sets, tgts, a_bits, pats)
        tau1, rho1, out = jax.vmap(
            lambda dr, ar, t, r, o: combine_side_results(dr, ar, t, r, caps, o)
        )(d_res, a_res, taus, rhos_s, ovf_i)
        # unstack inside the trace: per-member outputs, no eager slicing
        return (
            tuple(tree_index(tau1, i) for i in range(nc)),
            tuple(tree_index(rho1, i) for i in range(nc)),
            tuple(tree_index(out, i) for i in range(nc)),
        )

    return step


def make_sharded_cohort_step(
    plan: CompiledInterest,
    caps: StepCapacities,
    id_capacity: int,
    mesh,
    *,
    axis: str,
    n_shards: int,
    matcher: Optional[Callable] = None,
    delta: bool = False,
    n_frontiers: int = 1,
) -> Callable:
    """:func:`make_cohort_step` with the member evaluations inside shard_map.

    One cohort pass — all frontiers, all members — distributed over the
    whole mesh, bit-identical to the single-device step by construction:

    * each member's **τ replica is hash-partitioned** across the shards
      (SPO by subject, OPS by object — ``distributed.prepare_target_shards``,
      host-prepared and cached by the broker per (subscription, capacity));
      candidate-assertion probes route to the owner shard via the batched
      all_to_all probe (``distributed.make_routed_probe_batched``, one
      collective per hop spanning the whole member axis).  The partition key
      equals the probe's bound slot, so the owner holds the complete prefix
      range and even the fanout truncation order matches the unpartitioned
      index;
    * the **changeset rows stay replicated** but every shard *owns* only the
      rows whose subject hashes to it: the bank match passes are block-sliced
      across shards (1/n_shards of the match work each), the blocks
      all_gathered and stitched back at static offsets, then each shard
      zeroes the bits of rows it does not own (``row_mask`` in
      :func:`repro.kernels.ops.lane_bits_batched`).  Zero bits mean a row
      contributes no candidates, no signature scatters, and no outputs, so
      the masks partition the whole downstream evaluation without reshaping
      any executable input;
    * signature / edge tables OR-reduce across shards
      (``table_reduce`` hook), so gating decisions are global while
      candidate generation and classification stay shard-local;
    * per-shard outputs re-enter canonical form through one
      ``from_array`` per member (sorted + deduped + compacted), which erases
      the shard decomposition entirely — the merged stores, Δ/Υ algebra, and
      overflow flags match the single-device cohort step bit for bit.

    Signature matches :func:`make_cohort_step` except that the bank words
    are computed in-graph (no ``d_words`` operand) and the per-member τ
    partitions ride alongside the full replicas (which Υ still needs)::

        step(d_sets, a_sets, bank_dev, uniq_taus,
             uniq_tau_spo,   # int32[Nu, n_shards, t_cap, 3] subject-hashed
             uniq_tau_ops,   # int32[Nu, n_shards, t_cap, 3] object-hashed
             f_map, tgt_map, rhos, pats, lanes, active)
          -> (tau1s, rho1s, outs)

    Candidate dedup (``caps.dedup_candidates``) is rejected here: its pool
    overflow is counted per shard over shard-local candidate subsets, so a
    global pool overflow that no single shard sees would skip the broker's
    capacity-doubling retry and break bit-identity exactly in the overflow
    regime. Sharded dedup needs a count-reduce hook (ROADMAP follow-on).

    ``delta=True`` is the delta-chain variant (see :func:`make_cohort_step`):
    the per-frontier ``d_sets`` tuple is replaced by the shared union store
    plus its int32 membership bitmap (bits = the ``n_frontiers`` local
    frontier slots), and each shard's block-split bank pass consumes the
    UNION rows through one segmented match
    (:func:`repro.kernels.ops.pattern_bitmask_words_segmented`) — one
    compare pass per block regardless of how many frontiers fired, with the
    per-frontier word planes composed by masking in registers before the
    block gather-stitch::

        step(d_union,  # TripleStore — union D rows (replicated)
             d_seg,    # int32[|U|] membership bitmap, bit = frontier slot
             a_sets, bank_dev, uniq_taus, uniq_tau_spo, uniq_tau_ops,
             f_map, tgt_map, rhos, pats, lanes, active)
    """
    if caps.dedup_candidates:
        raise ValueError(
            "sharded cohort evaluation requires dedup_candidates == 0 "
            "(per-shard pools cannot detect global dedup overflow)"
        )
    eval_kw = dict(
        id_capacity=id_capacity,
        fanout=caps.fanout,
        pull_capacity=caps.pulls,
        matcher=matcher,
        dedup_candidates=caps.dedup_candidates,
        dynamic_patterns=True,
        probe_impl=make_routed_probe_batched(axis, n_shards),
        table_reduce=make_or_reduce(axis),
    )
    eval_d = make_side_evaluator(plan, out_capacity=caps.n_removed, **eval_kw)
    eval_a = make_side_evaluator(plan, out_capacity=caps.n_i, **eval_kw)

    def added_side_bits(my, i_spo, bank, lanes, active):
        """Block-sliced fused match+route over I rows, block-gathered and
        stitched at static offsets, then subject-hash ownership-masked —
        the per-shard lane-bits discipline shared by both shard bodies."""
        n_i_cap = i_spo.shape[1]
        blk_i = -(-n_i_cap // n_shards)
        starts_i = [min(i * blk_i, n_i_cap - blk_i) for i in range(n_shards)]
        i_loc = jax.lax.dynamic_slice_in_dim(i_spo, my * blk_i, blk_i, axis=1)
        a_loc = kops.pattern_lane_bits_batched(
            i_loc, bank, lanes, active, matcher=matcher
        )
        a_gather = jax.lax.all_gather(a_loc, axis)  # (n, Nc, blk_i)
        a_full = jnp.zeros((i_spo.shape[0], n_i_cap), jnp.uint32)
        for i in range(n_shards):
            a_full = jax.lax.dynamic_update_slice(
                a_full, a_gather[i], (0, starts_i[i])
            )
        own_i = (i_spo[:, :, 0] != PAD) & (i_spo[:, :, 0] % n_shards == my)
        return jnp.where(own_i, a_full, jnp.uint32(0))

    def local_tau_indexes(uq_spo, uq_ops, tgt_map):
        """This shard's τ partitions as per-member indexes (pre-sorted
        host-side), gathered from the unique-replica axis."""
        uqs, uqo = uq_spo[:, 0], uq_ops[:, 0]
        tgts_u = TripleIndex(
            spo=TripleStore(
                spo=uqs,
                n=jnp.sum(uqs[:, :, 0] != PAD, axis=1).astype(jnp.int32),
            ),
            ops=TripleStore(
                spo=uqo,
                n=jnp.sum(uqo[:, :, 0] != PAD, axis=1).astype(jnp.int32),
            ),
        )
        return tree_gather(tgts_u, tgt_map)

    def shard_body(
        d_spo, d_ns, i_spo, i_ns, uq_spo, uq_ops,
        bank, f_map, tgt_map, pats, lanes, active,
    ):
        my = jax.lax.axis_index(axis)
        nfp, d_cap = d_spo.shape[0], d_spo.shape[1]

        # deleted-side bank words: each shard matches one row block; the
        # blocks all_gather at 1/n_shards the full-tensor volume and stitch
        # back at static offsets (the tail shards' clamped blocks overlap,
        # but overlapping rows carry identical words, so overwrite is exact)
        blk_d = -(-d_cap // n_shards)
        starts_d = [min(i * blk_d, d_cap - blk_d) for i in range(n_shards)]
        d_loc = jax.lax.dynamic_slice_in_dim(d_spo, my * blk_d, blk_d, axis=1)
        w_loc = jax.vmap(
            lambda s: kops.pattern_bitmask_words(s, bank, matcher=matcher)
        )(d_loc)
        w_gather = jax.lax.all_gather(w_loc, axis)  # (n, nfp, blk_d, W)
        d_words = jnp.zeros((nfp, d_cap, w_loc.shape[-1]), jnp.uint32)
        for i in range(n_shards):
            d_words = jax.lax.dynamic_update_slice_in_dim(
                d_words, w_gather[i], starts_d[i], axis=1
            )

        # per-member views + subject-hash ownership masks
        d_mem_spo = jnp.take(d_spo, f_map, axis=0)
        own_d = (d_mem_spo[:, :, 0] != PAD) & (
            d_mem_spo[:, :, 0] % n_shards == my
        )
        d_bits = kops.lane_bits_batched(
            jnp.take(d_words, f_map, axis=0), lanes,
            active=active, row_mask=own_d,
        )

        a_bits = added_side_bits(my, i_spo, bank, lanes, active)
        tgt_mem = local_tau_indexes(uq_spo, uq_ops, tgt_map)
        d_store = TripleStore(spo=d_mem_spo, n=jnp.take(d_ns, f_map, axis=0))
        i_store = TripleStore(spo=i_spo, n=i_ns)
        d_res = jax.vmap(
            lambda m, t, b, p: eval_d(m, t, b, p)
        )(d_store, tgt_mem, d_bits, pats)
        a_res = jax.vmap(
            lambda m, t, b, p: eval_a(m, t, b, p)
        )(i_store, tgt_mem, a_bits, pats)
        return jax.tree.map(lambda t: t[None], (d_res, a_res))

    def shard_body_delta(
        du_spo, du_n, d_seg, i_spo, i_ns, uq_spo, uq_ops,
        bank, f_map, tgt_map, pats, lanes, active,
    ):
        my = jax.lax.axis_index(axis)
        d_cap = du_spo.shape[0]
        nc = lanes.shape[0]

        # union-side bank words: ONE segmented match per row block (the
        # per-frontier planes are composed by masking in registers), blocks
        # all_gathered at 1/n_shards the volume and stitched at static
        # offsets exactly like the stacked pass (overlapping clamped tail
        # blocks carry identical planes, so overwrite is exact)
        blk_d = -(-d_cap // n_shards)
        starts_d = [min(i * blk_d, d_cap - blk_d) for i in range(n_shards)]
        rows_loc = jax.lax.dynamic_slice_in_dim(
            du_spo, my * blk_d, blk_d, axis=0
        )
        seg_loc = jax.lax.dynamic_slice_in_dim(
            d_seg, my * blk_d, blk_d, axis=0
        )
        w_loc = kops.pattern_bitmask_words_segmented(
            rows_loc, bank, seg_loc, n_frontiers, matcher=matcher
        )  # (F, blk_d, W)
        w_gather = jax.lax.all_gather(w_loc, axis)  # (n, F, blk_d, W)
        d_words = jnp.zeros(
            (n_frontiers, d_cap, w_loc.shape[-1]), jnp.uint32
        )
        for i in range(n_shards):
            d_words = jax.lax.dynamic_update_slice_in_dim(
                d_words, w_gather[i], starts_d[i], axis=1
            )

        # every member evaluates the same union rows; subject-hash
        # ownership masks partition the downstream work across shards
        own_d = (du_spo[:, 0] != PAD) & (du_spo[:, 0] % n_shards == my)
        d_bits = kops.lane_bits_batched(
            jnp.take(d_words, f_map, axis=0), lanes,
            active=active, row_mask=jnp.broadcast_to(own_d[None], (nc, d_cap)),
        )

        a_bits = added_side_bits(my, i_spo, bank, lanes, active)
        tgt_mem = local_tau_indexes(uq_spo, uq_ops, tgt_map)
        d_store = TripleStore(spo=du_spo, n=du_n)  # shared union store
        i_store = TripleStore(spo=i_spo, n=i_ns)
        d_res = jax.vmap(
            lambda t, b, p: eval_d(d_store, t, b, p)
        )(tgt_mem, d_bits, pats)
        a_res = jax.vmap(
            lambda m, t, b, p: eval_a(m, t, b, p)
        )(i_store, tgt_mem, a_bits, pats)
        return jax.tree.map(lambda t: t[None], (d_res, a_res))

    store_spec = TripleStore(spo=P(axis), n=P(axis))
    side_spec = SideResult(
        interesting=store_spec, potential=store_spec, pulls=store_spec,
        overflow=P(axis),
    )
    rep = P()
    if delta:
        sharded_passes = shard_map_compat(
            shard_body_delta,
            mesh,
            in_specs=(
                rep, rep, rep, rep, rep,
                P(None, axis), P(None, axis),
                rep, rep, rep, rep, rep, rep,
            ),
            out_specs=(side_spec, side_spec),
        )
    else:
        sharded_passes = shard_map_compat(
            shard_body,
            mesh,
            in_specs=(
                rep, rep, rep, rep,
                P(None, axis), P(None, axis),
                rep, rep, rep, rep, rep, rep,
            ),
            out_specs=(side_spec, side_spec),
        )

    def merge_side(res: SideResult, out_cap: int, pull_cap: int) -> SideResult:
        """Union the per-shard results back into canonical per-member form."""

        def merge_store(st: TripleStore, cap: int):
            rows = jnp.swapaxes(st.spo, 0, 1).reshape(st.spo.shape[1], -1, 3)
            return jax.vmap(lambda r: from_array(r, cap))(rows)

        inter, ovf_i = merge_store(res.interesting, out_cap)
        pot, ovf_q = merge_store(res.potential, out_cap)
        pulls, ovf_p = merge_store(res.pulls, pull_cap)
        overflow = jnp.any(res.overflow, axis=0) | ovf_i | ovf_q | ovf_p
        return SideResult(
            interesting=inter, potential=pot, pulls=pulls, overflow=overflow
        )

    if delta:

        @jax.jit
        def step_delta(
            d_union: TripleStore,
            d_seg: jax.Array,
            a_sets: Tuple[TripleStore, ...],
            bank_dev: jax.Array,
            uniq_taus: Tuple[TripleStore, ...],
            uniq_tau_spo: jax.Array,
            uniq_tau_ops: jax.Array,
            f_map: jax.Array,
            tgt_map: jax.Array,
            rhos: Tuple[TripleStore, ...],
            pats: jax.Array,
            lanes: jax.Array,
            active: jax.Array,
        ):
            nc = lanes.shape[0]
            rhos_s = tree_stack(list(rhos))
            uniq_s = tree_stack(list(uniq_taus))
            a_stack = tree_stack(list(a_sets))
            a_mem = tree_gather(a_stack, f_map)
            i_sets, ovf_i = jax.vmap(lambda a, r: union(a, r, caps.n_i))(
                a_mem, rhos_s
            )
            d_res_sh, a_res_sh = sharded_passes(
                d_union.spo, d_union.n, d_seg, i_sets.spo, i_sets.n,
                uniq_tau_spo, uniq_tau_ops,
                bank_dev, f_map, tgt_map, pats, lanes, active,
            )
            d_res = merge_side(d_res_sh, caps.n_removed, caps.pulls)
            a_res = merge_side(a_res_sh, caps.n_i, caps.pulls)
            taus = tree_gather(uniq_s, tgt_map)
            tau1, rho1, out = jax.vmap(
                lambda dr, ar, t, r, o: combine_side_results(
                    dr, ar, t, r, caps, o
                )
            )(d_res, a_res, taus, rhos_s, ovf_i)
            return (
                tuple(tree_index(tau1, i) for i in range(nc)),
                tuple(tree_index(rho1, i) for i in range(nc)),
                tuple(tree_index(out, i) for i in range(nc)),
            )

        return step_delta

    @jax.jit
    def step(
        d_sets: Tuple[TripleStore, ...],
        a_sets: Tuple[TripleStore, ...],
        bank_dev: jax.Array,
        uniq_taus: Tuple[TripleStore, ...],
        uniq_tau_spo: jax.Array,
        uniq_tau_ops: jax.Array,
        f_map: jax.Array,
        tgt_map: jax.Array,
        rhos: Tuple[TripleStore, ...],
        pats: jax.Array,
        lanes: jax.Array,
        active: jax.Array,
    ):
        nc = lanes.shape[0]
        rhos_s = tree_stack(list(rhos))
        uniq_s = tree_stack(list(uniq_taus))
        d_stack = tree_stack(list(d_sets))
        a_stack = tree_stack(list(a_sets))
        a_mem = tree_gather(a_stack, f_map)
        i_sets, ovf_i = jax.vmap(lambda a, r: union(a, r, caps.n_i))(
            a_mem, rhos_s
        )
        d_res_sh, a_res_sh = sharded_passes(
            d_stack.spo, d_stack.n, i_sets.spo, i_sets.n,
            uniq_tau_spo, uniq_tau_ops,
            bank_dev, f_map, tgt_map, pats, lanes, active,
        )
        d_res = merge_side(d_res_sh, caps.n_removed, caps.pulls)
        a_res = merge_side(a_res_sh, caps.n_i, caps.pulls)
        taus = tree_gather(uniq_s, tgt_map)
        tau1, rho1, out = jax.vmap(
            lambda dr, ar, t, r, o: combine_side_results(dr, ar, t, r, caps, o)
        )(d_res, a_res, taus, rhos_s, ovf_i)
        return (
            tuple(tree_index(tau1, i) for i in range(nc)),
            tuple(tree_index(rho1, i) for i in range(nc)),
            tuple(tree_index(out, i) for i in range(nc)),
        )

    return step


def _assemble_cohort_statics(
    pat_rows: Sequence[np.ndarray],
    lane_rows: Sequence[Sequence[int]],
    tgt: Sequence[int],
    fmap: Sequence[int],
    ncp: int,
    nt: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """(f_map, tgt_map, pats, lanes, active) device inputs for one padded
    cohort.

    Single definition of the dummy-lane encoding (zeros + active=False),
    shared by the Broker's cached path and the frozen make_broker_step
    wrapper so the two can never diverge.
    """
    nm = len(pat_rows)
    f_map = np.zeros((ncp,), np.int32)
    tgt_map = np.zeros((ncp,), np.int32)
    pats = np.zeros((ncp, nt, 3), np.int32)
    lanes = np.zeros((ncp, nt), np.int32)
    active = np.zeros((ncp,), bool)
    for pos in range(nm):
        f_map[pos] = fmap[pos]
        tgt_map[pos] = tgt[pos]
        pats[pos] = pat_rows[pos]
        lanes[pos] = np.asarray(lane_rows[pos], np.int32)
        active[pos] = True
    return (
        jnp.asarray(f_map),
        jnp.asarray(tgt_map),
        jnp.asarray(pats),
        jnp.asarray(lanes),
        jnp.asarray(active),
    )


@partial(jax.jit, static_argnames=("slots",))
def _seg_local_bits(seg: jax.Array, slots: tuple) -> jax.Array:
    """Remap a frontier-chain membership bitmap from global frontier
    indices to a cohort's dense local frontier slots: output bit ``l`` is
    input bit ``slots[l]``. The sharded delta step's segmented pass reads
    local slots (they key ``f_map``), while the chain is built once per
    flush over the global frontier order."""
    out = jnp.zeros_like(seg)
    for l, fi in enumerate(slots):
        out = out | (((seg >> fi) & 1) << l)
    return out


_EMPTY_STORES: Dict[tuple, TripleStore] = {}


def _empty_cached(capacity: int, device=None) -> TripleStore:
    """Shared immutable empty store per (capacity, device) — cohort padding
    lanes; the placed broker keeps one copy committed per mesh device so
    padding slots never re-transfer at fire time."""
    key = (capacity, device)
    store = _EMPTY_STORES.get(key)
    if store is None:
        store = empty(capacity)
        if device is not None:
            store = jax.device_put(store, device)
        store = _EMPTY_STORES.setdefault(key, store)
    return store


_EMPTY_OUTPUTS: Dict[StepCapacities, EvalOutputs] = {}


def _empty_outputs(caps: StepCapacities) -> EvalOutputs:
    """Canonical all-empty :class:`EvalOutputs` at one capacity family.

    The broker's empty-batch fast path returns this for a fired frontier
    whose composed changeset has zero rows on both sides — nothing was
    added or removed, so nothing propagates and no executable runs. Store
    capacities match what the full evaluation would produce (``r``/``r_i``
    at ``n_removed``, ``r'`` at ``pulls``, ``a`` at ``n_i + pulls``,
    ``a_i`` at ``n_i``), so downstream consumers see identical shapes.
    """
    out = _EMPTY_OUTPUTS.get(caps)
    if out is None:
        out = _EMPTY_OUTPUTS.setdefault(
            caps,
            EvalOutputs(
                r=_empty_cached(caps.n_removed),
                r_i=_empty_cached(caps.n_removed),
                r_prime=_empty_cached(caps.pulls),
                a=_empty_cached(caps.n_i + caps.pulls),
                a_i=_empty_cached(caps.n_i),
                overflow=jnp.zeros((), bool),
            ),
        )
    return out


def _padded_bank_dev(patterns: np.ndarray) -> jax.Array:
    """Pad a bank array to a power-of-two (>= 32) lane count; the padding
    rows are all-PAD patterns that can never match a valid triple."""
    n_pad = max(32, next_pow2(patterns.shape[0]))
    out = np.full((n_pad, 3), PAD, np.int32)
    out[: patterns.shape[0]] = patterns
    return jnp.asarray(out)


def make_broker_step(
    bank: PatternBank,
    plans: Sequence[CompiledInterest],
    caps_list: Sequence[StepCapacities],
    id_capacities: Sequence[int],
    matcher: Optional[Callable] = None,
) -> Callable:
    """(D, A, (τ_k,), (ρ_k,)) -> ((τ'_k,), (ρ'_k,), (out_k,)) for a frozen
    subscriber set — the PR 1 entry point, now a thin composition of
    :func:`make_cohort_step` executables over a padded bank.

    Kept for golden/property tests and one-shot uses; the :class:`Broker`
    manages the same cohort steps through its executable cache instead, so
    membership churn does not rebuild unrelated cohorts.
    """
    n_subs = len(plans)
    assert n_subs == len(caps_list) == len(id_capacities) == len(bank.lanes)
    bank_dev = _padded_bank_dev(np.asarray(bank.patterns, np.int32))

    groups: Dict[tuple, List[int]] = {}
    for k, (plan, caps, id_cap) in enumerate(
        zip(plans, caps_list, id_capacities)
    ):
        key = (_plan_shape_key(plan), caps, id_cap)
        groups.setdefault(key, []).append(k)
    cohorts = [
        (tuple(idxs), plans[idxs[0]], caps_list[idxs[0]], id_capacities[idxs[0]])
        for idxs in groups.values()
    ]
    steps = [
        make_cohort_step(plan, caps, id_cap, matcher=matcher)
        for _, plan, caps, id_cap in cohorts
    ]
    # membership is frozen here, so the per-cohort static inputs (pattern
    # values, lane maps, member mask, identity tgt_map: no τ sharing in the
    # one-shot wrapper, single-frontier f_map) upload once
    statics = [
        _assemble_cohort_statics(
            [plans[k].patterns for k in idxs],
            [bank.lanes[k] for k in idxs],
            list(range(len(idxs))),
            [0] * len(idxs),
            next_pow2(len(idxs)),
            plan.n_total,
        )
        for idxs, plan, caps, _ in cohorts
    ]

    def step(
        d_set: TripleStore,
        a_set: TripleStore,
        taus: Tuple[TripleStore, ...],
        rhos: Tuple[TripleStore, ...],
    ):
        # fused pass 1: deleted side, shared by every cohort
        d_words = kops.pattern_bitmask_words(
            d_set.spo, bank_dev, matcher=matcher
        )
        tau1s = [None] * n_subs
        rho1s = [None] * n_subs
        outs = [None] * n_subs
        for (idxs, plan, caps, _), fn, (
            f_map,
            tgt_map,
            pats,
            lanes,
            active,
        ) in zip(cohorts, steps, statics):
            nm = len(idxs)
            ncp = next_pow2(nm)
            taus_c = tuple(taus[k] for k in idxs) + (
                _empty_cached(caps.tau),
            ) * (ncp - nm)
            rhos_c = tuple(rhos[k] for k in idxs) + (
                _empty_cached(caps.rho),
            ) * (ncp - nm)
            tau1_c, rho1_c, out_c = fn(
                (d_set,),
                (d_words,),
                (a_set,),
                bank_dev,
                taus_c,
                f_map,
                tgt_map,
                rhos_c,
                pats,
                lanes,
                active,
            )
            for pos, k in enumerate(idxs):
                tau1s[k] = tau1_c[pos]
                rho1s[k] = rho1_c[pos]
                outs[k] = out_c[pos]
        return tuple(tau1s), tuple(rho1s), tuple(outs)

    return step


class BrokerSubscription:
    """One registered interest inside the broker: plan, caps, policy, τ, ρ."""

    _serial_counter = itertools.count()

    def __init__(
        self,
        expr: InterestExpr,
        dictionary: Dictionary,
        caps: StepCapacities,
        policy: PushPolicy | None = None,
    ):
        self.expr = expr
        self.dictionary = dictionary
        self.caps = caps
        self.policy = policy if policy is not None else PushPolicy()
        # monotonic identity for host-side cache signatures (unlike id(),
        # never reused after garbage collection); plan_version tracks
        # recompiles the same way
        self.serial = next(BrokerSubscription._serial_counter)
        self.plan_version = 0
        self.plan = compile_interest(expr, dictionary)
        # cohort-grouping key, cached: rebuilding it per fire costs O(plan
        # rows) python per subscriber, which dominates large-fanout flushes
        self.shape_key = _plan_shape_key(self.plan)
        self.id_capacity = dictionary.id_capacity * caps.id_headroom
        self.tau = empty(caps.tau)
        self.rho = empty(caps.rho)
        # bumped on every τ assignment; keys the broker's τ-shard partition
        # cache, so only touched replicas ever re-partition
        self.tau_version = 0
        self.lanes: Tuple[int, ...] = ()  # bank lane map (broker-managed)
        self.since = 1  # first unconsumed changeset id (broker-managed)
        self.last_push_t = time.perf_counter()
        # shared-τ lineage: subscriptions attached to one target replica
        # share `share_tag`; `epoch` hashes the consumption history, so two
        # subscriptions share a build_index(τ) in the cohort step exactly
        # when their replica state is provably identical.
        self.share_tag: object = self
        self.epoch: int = 0
        # canonical lane-group signature (canonical-form key, caps, policy)
        # — the broker's automatic exact-duplicate collapse index; None when
        # the lattice is off
        self.canon_sig: Optional[tuple] = None
        # durable identity: broker-assigned, journaled, stable across
        # recovery (unlike `serial`, which is process-local)
        self.jid: int = -1
        # per-subscriber delivery callback (overrides the channel default);
        # ephemeral — not journaled, re-attach after recover()
        self.transport: Optional[Callable] = None

    def recompile(self, caps: StepCapacities | None = None) -> None:
        """Refresh plan/capacities after dictionary or capacity growth."""
        if caps is not None:
            self.caps = caps
        self.plan_version += 1
        self.plan = compile_interest(self.expr, self.dictionary)
        self.shape_key = _plan_shape_key(self.plan)
        self.id_capacity = self.dictionary.id_capacity * self.caps.id_headroom
        self.tau, _ = union(empty(self.caps.tau), self.tau, self.caps.tau)
        self.rho, _ = union(empty(self.caps.rho), self.rho, self.caps.rho)
        self.tau_version += 1

    def init_target(self, triples: np.ndarray) -> bool:
        """Load the initial RDFSlice-style subset into τ. True if caps grew."""
        grew = False
        while True:
            store, overflow = from_array(
                jnp.asarray(triples, jnp.int32), self.caps.tau
            )
            if not bool(overflow):
                self.tau = store
                self.tau_version += 1
                return grew
            self.recompile(self.caps.doubled())
            grew = True


@dataclasses.dataclass
class BrokerStats:
    """Per-call accounting for the fused pass (all evaluated subscribers)."""

    changeset_id: int
    n_subscribers: int
    n_lanes: int  # allocated bank lanes (incl. tombstones)
    n_lanes_raw: int  # sum of per-interest pattern counts
    total_removed: int
    total_added: int
    interesting_removed: int  # Σ_k |r_k| over evaluated subscribers
    interesting_added: int  # Σ_k |a_k| over evaluated subscribers
    elapsed_s: float  # wall time incl. rejit_s
    rejit_s: float = 0.0  # executable compile / bank rebuild time
    n_evaluated: int = 0  # subscribers whose policy fired
    n_deferred: int = 0  # subscribers whose batch kept accumulating
    n_cohort_passes: int = 0  # cohort executables invoked
    batch_grows: int = 0  # cumulative ChangesetBatch pow2 doublings
    batch_shrinks: int = 0  # cumulative ChangesetBatch decay re-homes
    # D-side bank-match volume this call: rows run through a match pass vs
    # the distinct rows across the fired frontiers. The stacked pass
    # re-matches shared suffix rows once per frontier (matched ≈ F × the
    # union on overlap-heavy streams); the delta chain matches each
    # distinct row once (matched == distinct), making dedup efficacy
    # directly observable. Counts repeat on capacity-overflow retries
    # (honest work accounting); single-changeset frontiers report their
    # raw-row upper bound, mirroring the capacity guards.
    rows_matched: int = 0
    rows_distinct: int = 0
    # lattice efficacy this call: cohort slots actually evaluated vs
    # subscriber deliveries those slots fanned out to. With the
    # subsumption lattice on, identical interests collapse into one lane
    # group, so distinct_interests tracks the distinct-interest pool while
    # fanout_copies tracks subscribers — their ratio is the O(1)-copies
    # win. Lattice off: one slot per subscriber, so the two are equal.
    # Counts repeat on capacity-overflow retries (honest work accounting).
    distinct_interests: int = 0
    fanout_copies: int = 0
    # unified sequence clock after this call (journal seq when journaling:
    # ingests, subscribes, and committed fires each consume one tick)
    seq: int = 0
    # fires this call that fell back to the per-interest seed path after
    # the bounded overflow-retry ceiling (degraded, still bit-identical)
    degraded_fires: int = 0


@dataclasses.dataclass
class _FrontierInput:
    """One fired consumption frontier, abstracted over residency.

    ``d_store`` / ``a_store`` produce the frontier's composed (D, A) at a
    requested capacity; the device-resident path re-homes sorted device
    stores (no transfer), the baseline path re-uploads host arrays.
    ``d_rows`` / ``a_rows`` bound the valid rows for the capacity guards.
    ``since`` is the frontier's first composed changeset id (its age — the
    delta chain picks the oldest fired frontier as the distinct-row
    union), and ``d_native`` hands out the composed D store at its native
    batch capacity for chain membership probes (None on the host
    round-trip baseline, which never chains).
    """

    idxs: List[int]
    d_rows: int
    a_rows: int
    d_store: Callable[[int], TripleStore]
    a_store: Callable[[int], TripleStore]
    since: int = 0
    d_native: Optional[Callable[[], TripleStore]] = None


def _stores_equal(a: TripleStore, b: TripleStore) -> bool:
    """Bit-equality of two canonical stores' valid rows (capacity-agnostic).

    Stores are lex-sorted and deduplicated, so set equality and row-array
    equality coincide; the common all-empty case short-circuits on the row
    counts without pulling the arrays to host.
    """
    if a is b:
        return True
    na, nb = int(a.n), int(b.n)
    if na != nb:
        return False
    if na == 0:
        return True
    return bool(np.array_equal(to_numpy(a), to_numpy(b)))


def _as_rows(arr) -> np.ndarray:
    """Normalize a changeset side to an int32 (N, 3) array; empty-friendly."""
    out = np.asarray(arr, dtype=np.int32)
    if out.size == 0:
        return np.zeros((0, 3), np.int32)
    if out.ndim != 2 or out.shape[1] != 3:
        raise ValueError(f"expected (N, 3) triples, got {out.shape}")
    return out


class Broker:
    """Host orchestrator batching all registered interests into fused passes.

    Drop-in counterpart of :class:`~repro.core.propagation.IrapEngine` for
    the many-subscriber regime: ``subscribe`` replaces ``register_interest``
    and ``process_changeset`` evaluates every *due* subscription (per its
    :class:`PushPolicy`) through cached per-cohort executables.

    ``cache_executables=False`` reproduces the PR 1 lifecycle — every
    membership change discards all compiled steps — and exists as the
    baseline for ``benchmarks/broker_churn.py``.

    ``deferred_device_resident=False`` reproduces the PR 2 deferred path —
    every scheduled fire round-trips its composed batch device→host→device
    and distinct frontiers run one sequential pass each — and exists as a
    baseline for ``benchmarks/broker_flush.py``. The default keeps composed
    batches on device end-to-end (:meth:`ChangesetBatch.device_stores` +
    :func:`repro.core.triples.rehome`) and stacks same-shape cohorts fired
    from different frontiers into one batched executable call.

    ``delta_frontiers=False`` reproduces the PR 3 *stacked* multi-frontier
    flush — one deleted-side bank pass per fired frontier, per-frontier
    store tuples gathered per member — and exists as the other baseline
    for ``benchmarks/broker_flush.py``. The default delta-encodes
    overlapping fired frontiers (module docstring, layer 4): one segmented
    bank pass over the distinct-row union, per-frontier words by
    membership masks, one shared union store per cohort — homed at the
    union's own pow2 row bucket rather than the per-subscriber guard
    capacity, so the D-side evaluation shapes track the distinct row
    volume the chain just proved. Dedup efficacy
    is observable through ``BrokerStats.rows_matched`` /
    ``rows_distinct`` (and the cumulative ``Broker.rows_matched`` /
    ``rows_distinct`` totals).

    ``subsume_interests=False`` reproduces the PR 5 *per-subscriber*
    broker — raw expressions, opt-in ``share_target`` only, one cohort
    slot per subscriber, no virtual bank lanes — and exists as the
    baseline for ``benchmarks/broker_fanout.py``. The default builds the
    interest-subsumption lattice (module docstring, layer 3): canonical
    expressions, automatic exact-duplicate lane groups with host-side
    result fanout, and containment-refined virtual lanes
    (:func:`repro.kernels.ops.lane_refine`). Lattice efficacy is
    observable through ``BrokerStats.distinct_interests`` /
    ``fanout_copies`` (and the cumulative broker totals of the same
    names).

    ``mesh`` (a 1-D jax device mesh) turns on multi-device evaluation:

    * by default every cohort is *placed* on one mesh device per the
      :class:`~repro.core.distributed.CohortPlacement` policy in
      ``placement`` (round-robin / load-balanced / pinned); the frontier
      pass dispatches cohort calls grouped by device, so same-fire cohorts
      run concurrently across the mesh and each cohort's τ/ρ state stays
      resident on its device between fires;
    * ``shard_cohorts=True`` instead runs every cohort pass *inside*
      shard_map over the whole mesh (:func:`make_sharded_cohort_step`):
      τ replicas hash-partition across the shards (partitions cached per
      (subscription, τ-version, capacity) so churn never re-partitions
      untouched replicas), bank matching block-splits across shards with
      block-gathered reassembly, and candidate probes route via all_to_all.

    Both modes are asserted bit-identical to the single-device broker
    (tests/test_broker_sharded.py, benchmarks/broker_shard.py). Per-frontier
    composed batches remain the delivery windows — the natural cross-host
    boundary for a future multi-process deployment.
    """

    def __init__(
        self,
        dictionary: Dictionary | None = None,
        matcher: Optional[Callable] = None,
        cache_executables: bool = True,
        deferred_device_resident: bool = True,
        delta_frontiers: bool = True,
        subsume_interests: bool = True,
        mesh=None,
        placement: CohortPlacement | None = None,
        shard_cohorts: bool = False,
        decay_patience: int = 2,
        journal: ChangesetJournal | None = None,
        channel=None,
        max_fire_retries: int = 8,
    ):
        self.dictionary = dictionary if dictionary is not None else Dictionary()
        self.matcher = matcher
        self.subs: List[BrokerSubscription] = []
        self.stats: List[BrokerStats] = []
        self.subsume_interests = subsume_interests
        self.bank = self._new_bank()
        # canonical lane-group signature -> lineage root (auto-collapse)
        self._share_index: Dict[tuple, BrokerSubscription] = {}
        self.cache_executables = cache_executables
        self.deferred_device_resident = deferred_device_resident
        self.delta_frontiers = delta_frontiers
        self.mesh = mesh
        self.shard_cohorts = shard_cohorts
        if mesh is not None:
            if len(mesh.axis_names) != 1:
                raise ValueError("Broker expects a 1-D device mesh")
            self._shard_axis = mesh.axis_names[0]
            self._n_shards = int(mesh.shape[self._shard_axis])
            self._devices = list(np.asarray(mesh.devices).reshape(-1))
        else:
            self._shard_axis = None
            self._n_shards = 1
            self._devices = []
        self.placement = (
            placement if placement is not None else CohortPlacement()
        )
        self.decay_patience = decay_patience
        self.device_passes: Dict[int, int] = {}  # device idx -> cohort passes
        self.batch_grows = 0  # ChangesetBatch pow2 doublings (cumulative)
        self.batch_shrinks = 0  # ChangesetBatch decay re-homes (cumulative)
        # cumulative D-side match volume vs distinct rows (dedup efficacy)
        self.rows_matched = 0
        self.rows_distinct = 0
        self._rows_matched_acc = 0
        self._rows_distinct_acc = 0
        # cumulative lattice efficacy: cohort slots evaluated vs subscriber
        # deliveries fanned out from them (see BrokerStats)
        self.distinct_interests = 0
        self.fanout_copies = 0
        self._distinct_acc = 0
        self._fanout_acc = 0
        # Σ plan.n_total over live subscriptions, maintained incrementally
        # (recomputing it per stats record is O(subscribers) python)
        self._lanes_raw = 0
        self._grow_seen: Dict[int, int] = {}  # frontier id -> folded grows
        # τ-shard partitions per (sub serial, τ version, cap, n_shards)
        self._tau_parts_cache: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._empty_parts_cache: Dict[tuple, jax.Array] = {}
        self._bank_dev_for: Dict[tuple, jax.Array] = {}  # (version, dev idx)
        # LRU-bounded: superseded keys (outgrown caps, old padded sizes)
        # eventually fall out instead of holding XLA executables forever;
        # evicting a hot key only costs a recompile, never correctness
        self._exec_cache: "OrderedDict[tuple, Callable]" = OrderedDict()
        self.exec_cache_max = 128
        # membership-static device arrays per (cohort, membership signature)
        self._static_arrays_cache: "OrderedDict[tuple, tuple]" = OrderedDict()
        # exact consumption-history interning: (epoch, first, last) -> new
        # epoch id, so equal histories — and only equal histories — share
        # an epoch (no probabilistic hash comparison). Only subscriptions
        # whose share_tag is held by >= 2 members track epochs (it exists
        # purely to group shared-τ replicas), ids are monotonic so pruning
        # can never alias a held epoch, and unreachable entries are pruned
        # at a size threshold.
        self._epoch_intern: Dict[tuple, int] = {}
        self._epoch_next = 0
        self.epoch_intern_max = 4096
        self._bank_dev: jax.Array | None = None
        # real-rows-only padded bank + (parents, residual) refine operands
        # for the deleted-side words pass (== _bank_dev / None without
        # virtual lanes); refreshed together with _bank_dev per version
        self._bank_real_dev: jax.Array | None = None
        self._refine_dev: Optional[Tuple[jax.Array, jax.Array]] = None
        self._bank_version = -1
        self._batches: Dict[int, ChangesetBatch] = {}
        # durability tier (module docstring, layer 6): one monotonic
        # sequence clock shared by stats, frontiers, and the journal —
        # subscribe/unsubscribe/ingest/committed-fire each consume a tick
        # whether or not a journal is attached, so journal-on and
        # journal-off brokers assign identical ids
        self.journal = journal
        self.channel = channel
        self.max_fire_retries = max_fire_retries
        self._seq = journal.last_seq if journal is not None else 0
        self._last_cid = 0  # seq of the last ingested changeset
        self._jid_next = 0  # durable subscriber ids (journaled)
        self._last_snapshot_seq = 0
        self._snapshot_keep_from = 1  # compaction floor (advanced by snapshot)
        self._replaying = False  # recovery replay: suppress journal/delivery
        self.degraded_fires = 0  # cumulative seed-path fallback fires
        self._degraded_acc = 0
        self._rejit_acc = 0.0
        self.rejit_count = 0  # executable compiles (cohort + bank words)
        self.cohort_compiles: Dict[tuple, int] = {}  # per cohort key
        self.words_compiles = 0  # shared D-side bank-pass compiles

    # -- interest manager ---------------------------------------------------

    def _new_bank(self):
        return (
            SubsumptionBank() if self.subsume_interests
            else IncrementalPatternBank()
        )

    def subscribe(
        self,
        expr: InterestExpr,
        caps: StepCapacities = StepCapacities(),
        initial_target: np.ndarray | None = None,
        policy: PushPolicy | None = None,
        share_target: bool = False,
        transport: Optional[Callable] = None,
        _jid: int | None = None,
    ) -> BrokerSubscription:
        """Register an interest; only its own cohort will (re)compile.

        With the subsumption lattice on (the default) the expression is
        replaced by its canonical form
        (:func:`repro.core.interest.canonicalize_expr`) before compiling, so
        expressions differing only in pattern order / variable naming share
        plans, bank lanes, and — via the automatic lineage join below —
        cohort slots. A new subscription auto-joins an existing lane group
        when its canonical key, capacities, policy, consumption frontier,
        and τ/ρ state are all provably equal to the group root's (the join
        is then a pure optimization: the evaluation it skips would have
        produced bit-identical results); from then on the group occupies
        one cohort slot per fire and results fan out to every member.

        ``share_target=True`` keeps its shared-replica semantics: the new
        subscription *adopts* an existing identical subscription's current
        τ/ρ state and frontier (rather than requiring them to match), the
        paper's many-readers-of-one-target-dataset case. Falls back to an
        independent subscription when no compatible root exists.
        """
        if self.shard_cohorts and caps.dedup_candidates:
            raise ValueError(
                "shard_cohorts=True requires caps.dedup_candidates == 0 "
                "(see make_sharded_cohort_step)"
            )
        # WAL discipline: consume one sequence tick and journal the call's
        # raw arguments *before* mutating broker state, so the durable
        # prefix at any boundary is replayable (replay re-runs this method
        # with the recorded args and lands on identical state)
        jid = self._jid_next if _jid is None else _jid
        self._seq += 1
        if self.journal is not None and not self._replaying:
            arrays = {}
            if initial_target is not None and np.asarray(initial_target).size:
                arrays["initial_target"] = np.asarray(
                    initial_target, np.int32
                )
            self.journal.append(
                "subscribe",
                meta={
                    "jid": jid,
                    "expr": _expr_to_json(expr),
                    "caps": _caps_to_json(caps),
                    "policy": _policy_to_json(policy),
                    "share_target": bool(share_target),
                },
                arrays=arrays,
                seq=self._seq,
            )
        self._jid_next = max(self._jid_next, jid + 1)
        canon_key = None
        if self.subsume_interests:
            expr, canon_key = canonicalize_expr(expr)
        sub = BrokerSubscription(expr, self.dictionary, caps, policy=policy)
        sub.jid = jid
        sub.transport = transport
        sub.since = self._seq + 1
        root = self._find_share_root(sub) if share_target else None
        if root is not None:
            sub.tau, sub.rho = root.tau, root.rho
            sub.share_tag, sub.epoch = root.share_tag, root.epoch
            sub.since, sub.last_push_t = root.since, root.last_push_t
        elif initial_target is not None and initial_target.size:
            sub.init_target(initial_target)
        if canon_key is not None:
            # init_target may have doubled caps, so the signature reads the
            # final capacities
            sub.canon_sig = (canon_key, sub.caps, sub.policy)
            if root is None:
                auto = self._auto_join_root(sub)
                if auto is not None:
                    sub.tau, sub.rho = auto.tau, auto.rho
                    sub.share_tag, sub.epoch = auto.share_tag, auto.epoch
            self._share_index.setdefault(sub.canon_sig, sub)
        sub.lanes = self.bank.add_plan(sub.plan)
        self.subs.append(sub)
        self._lanes_raw += sub.plan.n_total
        if not self.cache_executables:
            self._exec_cache.clear()  # PR 1 full-rebuild baseline behavior
        return sub

    def _auto_join_root(
        self, sub: BrokerSubscription
    ) -> BrokerSubscription | None:
        """The lane-group root ``sub`` may join without changing semantics.

        Joining shares the root's τ-lineage tag and epoch, which is sound
        exactly when the new subscription's observable state already equals
        the root's: same canonical interest + capacities + policy (the
        index key), same consumption frontier, and bit-equal τ/ρ. Anything
        less keeps the subscription independent — a missed collapse, never
        a wrong one.
        """
        root = self._share_index.get(sub.canon_sig)
        if (
            root is None
            or root.caps != sub.caps  # root may have outgrown the signature
            or not self._frontier_equal(root.since, sub.since)
            or not _stores_equal(root.tau, sub.tau)
            or not _stores_equal(root.rho, sub.rho)
        ):
            return None
        return root

    def _frontier_equal(self, a: int, b: int) -> bool:
        """Do two consumption frontiers denote the same pending suffix?

        Exactly equal frontiers trivially do. Beyond that, the unified
        sequence clock assigns non-changeset events (subscribes, fires)
        their own ticks, so two frontiers that both point past the last
        ingested changeset have *empty* pending suffixes and are
        equivalent — the next ingest re-keys both onto its cid
        (see :meth:`_apply_ingest`).
        """
        return a == b or min(a, b) > self._last_cid

    def _find_share_root(
        self, sub: BrokerSubscription
    ) -> BrokerSubscription | None:
        for s in self.subs:
            if (
                s.expr == sub.expr
                and s.caps == sub.caps
                and s.policy == sub.policy
                and np.array_equal(s.plan.patterns, sub.plan.patterns)
            ):
                return s
        return None

    def unsubscribe(self, sub: BrokerSubscription) -> None:
        """Remove one subscription; unrelated cohorts keep their executables."""
        self._seq += 1
        if self.journal is not None and not self._replaying:
            self.journal.append(
                "unsubscribe", meta={"jid": sub.jid}, seq=self._seq
            )
        if self.channel is not None:
            self.channel.forget(sub)
        self.subs.remove(sub)
        self.bank.remove_plan(sub.lanes)
        sub.lanes = ()
        self._lanes_raw -= sub.plan.n_total
        sig = sub.canon_sig
        if sig is not None and self._share_index.get(sig) is sub:
            # another member of the lane group (if any) becomes the root
            # future duplicates are checked against
            repl = next(
                (s for s in self.subs if s.canon_sig == sig), None
            )
            if repl is None:
                del self._share_index[sig]
            else:
                self._share_index[sig] = repl
        if not self.subs:
            # no live lane maps reference the bank: reset it outright so a
            # later first subscription starts from a fresh, compact bank
            self.bank = self._new_bank()
            self._bank_version = -1
            self._batches.clear()
        else:
            remap = self.bank.maybe_compact()
            if remap is not None:
                for s in self.subs:
                    s.lanes = tuple(remap[l] for l in s.lanes)
            self._sweep_batches(drained=False)
        if not self.cache_executables:
            self._exec_cache.clear()  # PR 1 full-rebuild baseline behavior

    # -- executable cache ---------------------------------------------------

    def _ensure_bank_dev(self, dev: int | None = None) -> jax.Array:
        if self._bank_dev is None or self._bank_version != self.bank.version:
            self._bank_dev = jnp.asarray(self.bank.patterns_padded())
            self._bank_real_dev = self._bank_dev
            self._refine_dev = None
            if isinstance(self.bank, SubsumptionBank):
                ra = self.bank.refine_arrays()
                if ra is not None:
                    self._bank_real_dev = jnp.asarray(
                        self.bank.real_padded()
                    )
                    self._refine_dev = (
                        jnp.asarray(ra[0]), jnp.asarray(ra[1])
                    )
            self._bank_version = self.bank.version
            self._bank_dev_for.clear()
        if dev is None:
            return self._bank_dev
        key = (self._bank_version, dev)
        placed = self._bank_dev_for.get(key)
        if placed is None:
            placed = self._bank_dev_for.setdefault(
                key, jax.device_put(self._bank_dev, self._devices[dev])
            )
        return placed

    def _tau_partitions(self, sub: BrokerSubscription, cap: int) -> tuple:
        """Hash-partitioned (SPO, OPS) shards of one subscription's τ.

        Cached per (subscription serial, τ version, capacity, mesh size):
        membership churn, bank churn, and fires of *other* subscriptions
        leave the key untouched, so only replicas whose τ actually changed
        (or whose capacity grew) ever re-partition. The host-side partition
        pass (``prepare_target_shards``) is the device-residency boundary of
        the sharded path — one τ pull per version, amortized over every fire
        until the next update.
        """
        key = (sub.serial, sub.tau_version, cap, self._n_shards)
        hit = self._tau_parts_cache.get(key)
        if hit is not None:
            self._tau_parts_cache.move_to_end(key)
            return hit
        spo, ops, _ = prepare_target_shards(
            to_numpy(sub.tau), self._n_shards, cap
        )  # shard cap == replica cap, so a partition can never overflow
        parts = (jnp.asarray(spo), jnp.asarray(ops))
        self._tau_parts_cache[key] = parts
        while len(self._tau_parts_cache) > self.exec_cache_max:
            self._tau_parts_cache.popitem(last=False)
        return parts

    def _empty_parts(self, cap: int) -> jax.Array:
        """All-PAD τ partition block for padded unique-target slots."""
        key = (cap, self._n_shards)
        block = self._empty_parts_cache.get(key)
        if block is None:
            block = self._empty_parts_cache.setdefault(
                key, jnp.full((self._n_shards, cap, 3), PAD, jnp.int32)
            )
        return block

    def _build_exec(self, key: tuple, builder: Callable, args: tuple):
        """Fetch-or-compile one executable; compile time goes to rejit_s.

        On a miss the step is AOT-lowered against the concrete ``args`` so
        the recorded time is pure compilation (evaluation stays outside);
        if ahead-of-time compilation is unavailable the jitted callable is
        cached instead and its first call pays the compile inline.
        """
        fn = self._exec_cache.get(key)
        if fn is not None:
            self._exec_cache.move_to_end(key)
            return fn
        t0 = time.perf_counter()
        jitted = builder()
        try:
            fn = jitted.lower(*args).compile()
        except (AttributeError, NotImplementedError):
            # AOT lowering unavailable on this jax/backend only — genuine
            # compile errors must propagate. The fallback's first call pays
            # its compile inline (inflating elapsed_s, not rejit_s).
            fn = jitted
        self._exec_cache[key] = fn
        while len(self._exec_cache) > self.exec_cache_max:
            self._exec_cache.popitem(last=False)
        self._rejit_acc += time.perf_counter() - t0
        self.rejit_count += 1
        return fn

    # -- changeset manager + scheduler --------------------------------------

    def process_changeset(
        self, removed: np.ndarray, added: np.ndarray
    ) -> List[Optional[EvalOutputs]]:
        """Ingest one changeset; evaluate every subscriber whose policy fires.

        Returns one entry per subscriber, in subscription order: the
        :class:`EvalOutputs` of its (possibly batched) evaluation — each
        bit-identical to what the seed per-interest engine would produce for
        the same composed changeset — or None when the subscriber's policy
        deferred it (its pending batch keeps accumulating). An empty broker
        and 0-row ``removed``/``added`` sides are all well-defined: a fire
        whose composed batch is empty on both sides skips statics and
        executables entirely and returns canonical all-empty outputs (τ/ρ
        untouched — an empty changeset propagates nothing).
        """
        removed, added = _as_rows(removed), _as_rows(added)
        if self.channel is not None and not self._replaying:
            # backpressure: pump due retries first, and block (on the
            # channel's injected clock) while the in-flight retry queue is
            # over its bound — each pumped retry either acks or progresses
            # toward quarantine, both of which shrink the queue
            self._service_channel()
        self._seq += 1
        cid = self._seq
        if self.journal is not None and not self._replaying:
            # write-ahead: the changeset is durable before any batch sees it
            self.journal.append(
                "ingest",
                arrays={"removed": removed, "added": added},
                seq=cid,
            )
        if not self.subs:
            self._last_cid = cid
            return []
        t0 = time.perf_counter()
        self._rejit_acc = 0.0
        self._rows_matched_acc = self._rows_distinct_acc = 0
        self._distinct_acc = self._fanout_acc = 0
        self._degraded_acc = 0

        self._apply_ingest(removed, added, cid)

        now = time.perf_counter()
        fired = []
        for k, s in enumerate(self.subs):
            batch = self._batches.get(s.since)
            if batch is not None and s.policy.fires(
                batch.n_changesets, now - s.last_push_t
            ):
                if self.channel is not None and not self.channel.eligible(s):
                    continue  # quarantined / backing off: frontier pins
                fired.append(k)
        results, n_passes = self._fire(fired)
        self._sweep_batches(drained=bool(fired))
        self._record_stats(
            cid, removed, added, results, fired, n_passes, t0
        )
        return results

    def _apply_ingest(
        self, removed: np.ndarray, added: np.ndarray, cid: int
    ) -> None:
        """Layer 4: accumulate one changeset into every pending frontier.

        The unified clock makes changeset ids non-contiguous (subscribe and
        fire events consume ticks too), so a frontier pointing at a
        non-changeset seq — a fresh subscription, or a fully-drained
        subscriber — *re-keys* onto the first changeset that actually
        arrives: any subscriber with ``since <= cid`` and no pending batch
        provably has an empty pending suffix (every ingested changeset
        with id >= its frontier is in a batch it references), so adopting
        ``since = cid`` is the identity on its pending window.
        """
        for batch in self._batches.values():
            batch.extend(removed, added, cid)
        waiting = [
            s
            for s in self.subs
            if s.since not in self._batches and s.since <= cid
        ]
        if waiting:
            self._batches[cid] = ChangesetBatch.fresh(removed, added, cid)
            for s in waiting:
                s.since = cid
        self._last_cid = cid

    def _service_channel(self) -> None:
        """Pump due delivery retries; block while the retry queue is full.

        Called on the ingest path before consuming a sequence tick. Every
        flush of due subscribers either acks them (clearing their pending
        state) or fails them one step closer to quarantine, so the
        backpressure loop strictly drains and terminates.
        """
        ch = self.channel
        due = [s for s in self.subs if ch.retry_due(s)]
        if due:
            self.flush(due)
        if ch.max_in_flight is None:
            return
        while ch.in_flight() >= ch.max_in_flight:
            ch.wait_for_retry()
            due = [s for s in self.subs if ch.retry_due(s)]
            if not due:
                break
            self.flush(due)

    def flush(
        self, subs: Sequence[BrokerSubscription] | None = None
    ) -> List[Optional[EvalOutputs]]:
        """Drain pending batches now, regardless of policy.

        Evaluates every given subscription (default: all) that has at least
        one pending changeset; returns one entry per subscriber in
        subscription order (None where nothing was pending). Stale handles
        (already unsubscribed) are skipped, consistent with None semantics.
        A flush with nothing pending — and any fired frontier whose
        composed batch is empty — returns without building statics or
        touching executables (zero cohort passes).
        """
        if subs is None:
            targets = list(range(len(self.subs)))
        else:
            wanted = {id(s) for s in subs}
            targets = [
                k for k, s in enumerate(self.subs) if id(s) in wanted
            ]
        t0 = time.perf_counter()
        self._rejit_acc = 0.0
        self._rows_matched_acc = self._rows_distinct_acc = 0
        self._distinct_acc = self._fanout_acc = 0
        self._degraded_acc = 0
        fired = [k for k in targets if self.subs[k].since in self._batches]
        if self.channel is not None and not self._replaying:
            fired = [
                k for k in fired if self.channel.eligible(self.subs[k])
            ]
        results, n_passes = self._fire(fired)
        self._sweep_batches(drained=bool(fired))
        if fired:
            # the committed fire consumed its own sequence tick (and
            # journal record) inside _fire, so stats see the advanced clock
            z = np.zeros((0, 3), np.int32)
            self._record_stats(
                self._seq, z, z, results, fired, n_passes, t0
            )
        return results

    def _fire(
        self, fired: List[int]
    ) -> Tuple[List[Optional[EvalOutputs]], int]:
        results: List[Optional[EvalOutputs]] = [None] * len(self.subs)
        if not fired:
            return results, 0
        groups: Dict[int, List[int]] = {}
        for k in fired:
            groups.setdefault(self.subs[k].since, []).append(k)

        def group_order(since: int):
            # priority lanes drain first, then oldest frontier
            has_priority = any(
                self.subs[k].policy.priority for k in groups[since]
            )
            return (not has_priority, since)

        ordered = sorted(groups, key=group_order)
        # empty-batch fast path: a composed batch with zero rows on both
        # sides delivers nothing — skip statics, executables, and passes
        # entirely and hand its subscribers canonical empty outputs (their
        # τ/ρ are untouched; consuming the batch is composition-neutral,
        # <∅, ∅> composed with any future changeset is that changeset)
        outs: Dict[int, EvalOutputs] = {}
        fronts = []
        for since in ordered:
            batch = self._batches[since]
            d_rows, a_rows = batch.row_bounds()
            if d_rows == 0 and a_rows == 0:
                for k in groups[since]:
                    outs[k] = _empty_outputs(self.subs[k].caps)
                continue
            fronts.append(self._frontier_input(groups[since], batch))
        staged: Dict[int, Tuple[TripleStore, TripleStore]] = {}
        if not fronts:
            n_passes = 0
        elif self.deferred_device_resident:
            # all fired frontiers in one evaluation: same-shape cohorts
            # stack across frontiers into one batched executable call
            o, staged, n_passes = self._evaluate_frontiers(fronts)
            outs.update(o)
        else:
            # PR 2 baseline: one sequential pass per frontier
            n_passes = 0
            for fr in fronts:
                o, st, passes = self._evaluate_frontiers([fr])
                outs.update(o)
                staged.update(st)
                n_passes += passes

        # delivery gate (module docstring, layer 6): outputs are handed to
        # the channel BEFORE any state commits, so a failed delivery needs
        # no rollback — the subscriber is simply not committed: its τ/ρ
        # stay, its frontier pins, its batch keeps composing, and the next
        # eligible fire re-delivers the composed window (idempotent for
        # the receiver by Def-6 composition). Without a channel — and
        # during recovery replay — every fired subscriber acks.
        deliver = self.channel is not None and not self._replaying
        acked: List[int] = []
        for since in ordered:
            for k in groups[since]:
                if not deliver or self.channel.deliver(
                    self.subs[k], outs[k]
                ):
                    acked.append(k)
        if acked:
            # commit point: the fire consumes one sequence tick, durably
            # recording exactly the acked frontier advances; a crash
            # before this append re-fires (at-least-once), a crash after
            # it replays the evaluation without re-delivering
            self._seq += 1
            if self.journal is not None and not self._replaying:
                self.journal.append(
                    "fire",
                    meta={
                        "fires": [
                            [
                                self.subs[k].jid,
                                self._batches[self.subs[k].since].last_id
                                + 1,
                            ]
                            for k in acked
                        ]
                    },
                    seq=self._seq,
                )
        acked_set = set(acked)
        self._commit_staged(
            {k: staged[k] for k in acked if k in staged}
        )
        now = time.perf_counter()
        tag_refs: Dict[int, int] = {}
        for s in self.subs:
            tag_refs[id(s.share_tag)] = tag_refs.get(id(s.share_tag), 0) + 1
        for since in ordered:
            batch = self._batches[since]
            for k in groups[since]:
                if k not in acked_set:
                    continue
                results[k] = outs[k]
                s = self.subs[k]
                s.since = batch.last_id + 1
                s.last_push_t = now
                if tag_refs[id(s.share_tag)] > 1:
                    hist = (s.epoch, batch.first_id, batch.last_id)
                    epoch = self._epoch_intern.get(hist)
                    if epoch is None:
                        self._epoch_next += 1
                        epoch = self._epoch_intern[hist] = self._epoch_next
                    s.epoch = epoch
        if len(self._epoch_intern) > self.epoch_intern_max:
            # entries whose parent epoch no subscriber holds can never be
            # looked up again (lookups key on a live subscriber's epoch)
            held = {s.epoch for s in self.subs}
            self._epoch_intern = {
                hist: e
                for hist, e in self._epoch_intern.items()
                if hist[0] in held
            }
        return results, n_passes

    def _frontier_input(
        self, idxs: List[int], batch: ChangesetBatch
    ) -> "_FrontierInput":
        """One fired frontier as evaluator input.

        Device-resident (default): the batch's already-lex-sorted composed
        device stores re-home (pad/slice, never re-sort, never transfer) to
        whatever capacity the evaluation needs. Round-trip baseline: the
        composed batch is pulled to host and re-uploaded/re-sorted per fire
        (the PR 2 behavior).
        """
        if self.deferred_device_resident:
            d_rows, a_rows = batch.row_bounds()
            return _FrontierInput(
                idxs=idxs,
                d_rows=d_rows,
                a_rows=a_rows,
                d_store=lambda cap: rehome(batch.device_stores()[0], cap),
                a_store=lambda cap: rehome(batch.device_stores()[1], cap),
                since=batch.first_id,
                d_native=lambda: batch.device_stores()[0],
            )
        d_np, a_np = batch.arrays()
        return _FrontierInput(
            idxs=idxs,
            d_rows=int(d_np.shape[0]),
            a_rows=int(a_np.shape[0]),
            d_store=lambda cap: from_array(jnp.asarray(d_np, jnp.int32), cap)[0],
            a_store=lambda cap: from_array(jnp.asarray(a_np, jnp.int32), cap)[0],
            since=batch.first_id,
        )

    def _sweep_batches(self, drained: bool) -> None:
        """Batch lifecycle bookkeeping at one orchestration point.

        Folds every live batch's capacity-doubling count into the broker
        totals (before GC, so growth on a just-consumed frontier is not
        lost), drops batches no subscriber references, and — only when this
        call actually drained something, keeping the per-changeset ingest
        path free of device-scalar syncs — runs the capacity-decay check on
        the surviving deferred frontiers
        (:meth:`~repro.core.propagation.ChangesetBatch.maybe_decay`).
        """
        for since, b in self._batches.items():
            seen = self._grow_seen.get(since, 0)
            if b.grow_count > seen:
                self.batch_grows += b.grow_count - seen
                self._grow_seen[since] = b.grow_count
        live = {s.since for s in self.subs}
        self._batches = {
            since: b for since, b in self._batches.items() if since in live
        }
        self._grow_seen = {
            since: g
            for since, g in self._grow_seen.items()
            if since in self._batches
        }
        if drained:
            for b in self._batches.values():
                if b.maybe_decay(self.decay_patience):
                    self.batch_shrinks += 1

    # -- evaluator ----------------------------------------------------------

    def _static_arrays(
        self,
        ckey: tuple,
        fk: List[Tuple[int, int]],
        f_list: List[int],
        upos: Dict[int, int],
        ncp: int,
        nt: int,
        device=None,
    ):
        """Membership-static device inputs for one cohort invocation.

        f_map / pats / lanes / tgt_map / active change only with membership,
        frontier grouping, plan recompiles, bank compaction, or shared-τ
        regrouping — all covered by the cache key below — so the
        steady-state path skips the per-call numpy rebuild and
        host-to-device transfers. Keyed by the full membership signature
        (not just the cohort), so same-shape cohorts fired from different
        frontier combinations (mixed cadences) each keep their own entry
        instead of evicting one another; the LRU bound reclaims superseded
        signatures.
        """
        subs = self.subs
        key = (
            ckey,
            tuple(subs[k].serial for _, k in fk),
            tuple(subs[k].plan_version for _, k in fk),
            tuple(upos[k] for _, k in fk),
            tuple(f_list),
            self.bank.version,
        )
        cached = self._static_arrays_cache.get(key)
        if cached is not None:
            self._static_arrays_cache.move_to_end(key)
            return cached
        if isinstance(self.bank, SubsumptionBank):
            # encoded lane ids (virtual >= REFINE_BASE) -> dense extended
            # row indices; the cache key's bank.version covers validity
            lane_rows = [
                self.bank.resolve_lanes(subs[k].lanes) for _, k in fk
            ]
        else:
            lane_rows = [subs[k].lanes for _, k in fk]
        arrays = _assemble_cohort_statics(
            [subs[k].plan.patterns for _, k in fk],
            lane_rows,
            [upos[k] for _, k in fk],
            f_list,
            ncp,
            nt,
        )
        if device is not None:
            # committed to the cohort's placed device once, re-used per fire
            arrays = jax.device_put(arrays, device)
        self._static_arrays_cache[key] = arrays
        while len(self._static_arrays_cache) > self.exec_cache_max:
            self._static_arrays_cache.popitem(last=False)
        return arrays

    def _evaluate_frontiers(
        self, fronts: List[_FrontierInput]
    ) -> Tuple[
        Dict[int, EvalOutputs],
        Dict[int, Tuple[TripleStore, TripleStore]],
        int,
    ]:
        """All fired frontiers through every due cohort; staged results.

        Returns ``(outs, staged, n_passes)``: per-subscriber outputs, the
        staged (τ', ρ') updates, and the executable pass count. Nothing is
        committed here — :meth:`_fire` commits the staged state only for
        subscribers whose delivery acked (:meth:`_commit_staged`), which is
        what makes a failed delivery rollback-free.

        The frontier axis is folded into each cohort's member axis: one
        stacked bank pass covers every frontier's deleted side, and each
        shape cohort runs ONE executable call spanning all frontiers it
        fires from (members gather their frontier's slices via ``f_map``).
        The round-trip baseline calls this with single-frontier lists, so
        both paths share executables, statics, and commit discipline.

        With a mesh the pass is placement-aware: cohort calls are grouped
        by their :class:`~repro.core.distributed.CohortPlacement` device —
        dispatched in device order with fully committed inputs, so the
        asynchronously-running executables overlap across the mesh — or,
        under ``shard_cohorts=True``, every cohort call runs inside
        shard_map over the whole mesh with hash-partitioned τ shards.
        """
        subs = self.subs
        # matcher identity is baked into compiled steps, so it must be part
        # of every executable key (caches may be shared across brokers)
        mkey = id(self.matcher) if self.matcher is not None else None
        sharded = self.mesh is not None and self.shard_cohorts
        placed = self.mesh is not None and not self.shard_cohorts
        # delta-chain eligibility: >= 2 overlapping frontiers on the
        # device-resident path (a single frontier has nothing to dedup and
        # keeps the eager executables untouched); the int32 membership
        # bitmap caps the chain at 32 frontier slots
        delta_ok = (
            self.delta_frontiers
            and self.deferred_device_resident
            and len(fronts) >= 2
            and next_pow2(len(fronts)) <= 32
            and all(fr.d_native is not None for fr in fronts)
        )
        n_passes = 0  # counts abandoned overflow-retry attempts too
        n_retries = 0  # whole-fire overflow re-runs (bounded ceiling)
        front_of = {k: fr for fr in fronts for k in fr.idxs}
        while True:
            for fr in fronts:
                for k in fr.idxs:  # host-side capacity guard
                    s = subs[k]
                    while (
                        fr.d_rows > s.caps.n_removed
                        or fr.a_rows > s.caps.n_added
                    ):
                        s.recompile(s.caps.doubled())
                for k in fr.idxs:  # dictionary growth guard
                    if self.dictionary.id_capacity > subs[k].id_capacity:
                        subs[k].recompile()
            bank_dev = self._ensure_bank_dev()
            n_words_p = bank_dev.shape[0] // 32
            # deleted-side words inputs: when the subsumption bank holds
            # virtual lanes, the words pass runs over the REAL rows only
            # and lane_refine produces the virtual planes (parent word AND
            # residual compare), concatenated after the real planes — the
            # result reproduces the extended-bank word layout bit for bit,
            # at residual cost instead of full bank width
            bank_real = self._bank_real_dev
            refine = self._refine_dev
            n_words_r = bank_real.shape[0] // 32

            all_idx = [k for fr in fronts for k in fr.idxs]
            d_cap = max(subs[k].caps.n_removed for k in all_idx)
            nf = len(fronts)
            nfp = next_pow2(nf)

            # delta-encoded frontier chain: the fired frontiers' D sides
            # overlap (suffix composition), so build the distinct-row
            # union + per-frontier membership bitmap and match each row
            # ONCE; fall back to the stacked pass if containment fails
            # (the chain proves it instead of assuming Def-6 nesting).
            # The union is homed at its own pow2 row bucket, NOT the
            # per-subscriber guard capacity: one store serves every
            # member, so the whole D-side evaluation — candidate vectors,
            # probes, pull sorts — runs at distinct-row shapes instead of
            # F guard-capacity stores (the containment check doubles as
            # the proof that the bucket holds every frontier's rows)
            chain = None
            u_cap = d_cap
            if delta_ok:
                base_fi = min(range(nf), key=lambda i: fronts[i].since)
                u_cap = max(64, next_pow2(fronts[base_fi].d_rows))
                c = build_frontier_chain(
                    [fr.d_native() for fr in fronts], base_fi, u_cap
                )
                if c.covered:
                    chain = c
                else:
                    u_cap = d_cap
            if chain is not None:
                matched = distinct = fronts[base_fi].d_rows
            else:
                matched = sum(fr.d_rows for fr in fronts)
                distinct = max((fr.d_rows for fr in fronts), default=0)
            self._rows_matched_acc += matched
            self._rows_distinct_acc += distinct
            self.rows_matched += matched
            self.rows_distinct += distinct

            # fused pass 1 over the deleted side. Delta chain: ONE
            # segmented bank pass over the union rows emits every
            # frontier's membership-masked words (padding slots' bits are
            # simply absent from the bitmap). Stacked fallback: one bank
            # pass per frontier, sliced per cohort; padding slots carry
            # empty stores. The sharded path computes its words in-graph
            # instead (block-split across shards, block-gather-stitched),
            # so it skips this pass either way.
            d_stores = None
            if chain is None:
                d_stores = [fr.d_store(d_cap) for fr in fronts]
            d_words_all = None
            if not sharded and chain is not None:
                wkey = ("words-seg", u_cap, n_words_p, n_words_r, nfp, mkey)
                if refine is None:
                    def words_builder():
                        return jax.jit(
                            lambda spo, seg, b: (
                                kops.pattern_bitmask_words_segmented(
                                    spo, b, seg, nfp, matcher=self.matcher
                                )
                            )
                        )

                    wargs = (chain.union.spo, chain.seg, bank_real)
                else:
                    # refined planes inherit each frontier's membership
                    # mask for free: a union row outside frontier f has
                    # zero real bits, so its parent bit — and therefore
                    # its refined bit — is already zero
                    def words_builder():
                        def f(spo, seg, b, par, res):
                            w = kops.pattern_bitmask_words_segmented(
                                spo, b, seg, nfp, matcher=self.matcher
                            )
                            wv = jax.vmap(
                                lambda plane: kops.lane_refine(
                                    spo, plane, par, res
                                )
                            )(w)
                            return jnp.concatenate([w, wv], axis=-1)

                        return jax.jit(f)

                    wargs = (chain.union.spo, chain.seg, bank_real) + refine
                miss = wkey not in self._exec_cache
                words_fn = self._build_exec(wkey, words_builder, wargs)
                if miss:
                    self.words_compiles += 1
                # (nfp, u_cap, W) — frontier fi's words over the UNION rows
                d_words_all = words_fn(*wargs)
            elif not sharded:
                d_spos = tuple(st.spo for st in d_stores) + (
                    _empty_cached(d_cap).spo,
                ) * (nfp - nf)
                wkey = ("words", d_cap, n_words_p, n_words_r, nfp, mkey)
                if refine is None:
                    def words_builder():
                        return jax.jit(
                            lambda spos, b: jax.vmap(
                                lambda spo: kops.pattern_bitmask_words(
                                    spo, b, matcher=self.matcher
                                )
                            )(jnp.stack(spos))
                        )

                    wargs = (d_spos, bank_real)
                else:
                    def words_builder():
                        def one(spo, b, par, res):
                            w = kops.pattern_bitmask_words(
                                spo, b, matcher=self.matcher
                            )
                            return jnp.concatenate(
                                [w, kops.lane_refine(spo, w, par, res)],
                                axis=-1,
                            )

                        return jax.jit(
                            lambda spos, b, par, res: jax.vmap(
                                lambda spo: one(spo, b, par, res)
                            )(jnp.stack(spos))
                        )

                    wargs = (d_spos, bank_real) + refine
                miss = wkey not in self._exec_cache
                words_fn = self._build_exec(wkey, words_builder, wargs)
                if miss:
                    self.words_compiles += 1
                d_words_all = words_fn(*wargs)  # (nfp, d_cap, W)

            # per-frontier added sides, cached per cohort capacity
            a_cache: Dict[Tuple[int, int], TripleStore] = {}

            def a_of(fi: int, cap: int) -> TripleStore:
                if (fi, cap) not in a_cache:
                    a_cache[(fi, cap)] = fronts[fi].a_store(cap)
                return a_cache[(fi, cap)]

            cohorts: Dict[tuple, List[Tuple[int, int]]] = {}
            for fi, fr in enumerate(fronts):
                for k in fr.idxs:
                    s = subs[k]
                    key = (s.shape_key, s.caps, s.id_capacity)
                    cohorts.setdefault(key, []).append((fi, k))

            # placement: sticky cohort -> device assignment, calls grouped
            # (and therefore dispatched) by device so the mesh runs cohorts
            # concurrently; the sharded path spans every device per call
            cohort_items = list(cohorts.items())
            cohort_dev: Dict[tuple, Optional[int]] = {}
            for key, fk in cohort_items:
                if placed:
                    cohort_dev[key] = self.placement.assign(
                        key, next_pow2(len(fk)), len(self._devices)
                    )
                else:
                    cohort_dev[key] = None
            if placed:
                cohort_items.sort(key=lambda kv: cohort_dev[kv[0]])

            staged: Dict[int, Tuple[TripleStore, TripleStore]] = {}
            outs: Dict[int, EvalOutputs] = {}
            overflowed: List[int] = []
            for (skey, caps, id_cap), fk in cohort_items:
                dev = cohort_dev[(skey, caps, id_cap)]
                device = self._devices[dev] if dev is not None else None
                rep = subs[fk[0][1]]
                nt = rep.plan.n_total
                # frontier slots this cohort actually uses -> dense local
                # slots, so the padded frontier axis stays minimal
                fs_used = sorted({fi for fi, _ in fk})
                fslot = {fi: i for i, fi in enumerate(fs_used)}
                nfc = len(fs_used)
                nfcp = next_pow2(nfc)
                # unique target replicas (shared-τ lane groups) in this
                # cohort; rep_fk holds each group's first (frontier, sub)
                ugroups: List[List[int]] = []
                rep_fk: List[Tuple[int, int]] = []
                upos: Dict[int, int] = {}
                seen: Dict[tuple, int] = {}
                for fi, k in fk:
                    s = subs[k]
                    gk = (fi, id(s.share_tag), s.epoch)
                    if gk not in seen:
                        seen[gk] = len(ugroups)
                        ugroups.append([])
                        rep_fk.append((fi, k))
                    upos[k] = seen[gk]
                    ugroups[seen[gk]].append(k)
                if self.subsume_interests:
                    # lattice group collapse: ONE cohort slot per lane
                    # group. Members of a group provably share plan
                    # values, lanes, caps, τ, ρ, and frontier — that is
                    # exactly what the (share_tag, epoch) lineage
                    # certifies — so their slots would compute identical
                    # results; the commit loop below fans the
                    # representative's outputs out to every member, making
                    # executable work a function of distinct interests and
                    # delivery O(1) copies per interest.
                    eval_fk = rep_fk
                    eval_upos = {
                        k: i for i, (_, k) in enumerate(rep_fk)
                    }
                else:
                    eval_fk, eval_upos = fk, upos
                members = [k for _, k in eval_fk]
                f_list = [fslot[fi] for fi, _ in eval_fk]
                nm, nu = len(members), len(ugroups)
                ncp, nup = next_pow2(nm), next_pow2(nu)
                self._distinct_acc += nm
                self._fanout_acc += len(fk)
                self.distinct_interests += nm
                self.fanout_copies += len(fk)

                d_sets = None
                if chain is None:
                    d_sets = tuple(
                        TripleStore(
                            spo=d_stores[fi].spo[: caps.n_removed],
                            n=d_stores[fi].n,
                        )
                        for fi in fs_used
                    ) + (_empty_cached(caps.n_removed, device),) * (
                        nfcp - nfc
                    )
                a_sets = tuple(a_of(fi, caps.n_added) for fi in fs_used) + (
                    _empty_cached(caps.n_added, device),
                ) * (nfcp - nfc)
                uniq_taus = tuple(subs[g[0]].tau for g in ugroups) + (
                    _empty_cached(caps.tau, device),
                ) * (nup - nu)
                rhos_c = tuple(subs[k].rho for k in members) + (
                    _empty_cached(caps.rho, device),
                ) * (ncp - nm)
                if sharded:
                    if chain is not None:
                        ckey = (
                            "cohort-sh-delta", skey, caps, id_cap, ncp, nup,
                            nfcp, n_words_p, u_cap, self._n_shards, mkey,
                        )
                    else:
                        ckey = (
                            "cohort-sh", skey, caps, id_cap, ncp, nup, nfcp,
                            n_words_p, self._n_shards, mkey,
                        )
                    (
                        f_map_d, tgt_map_d, pats_d, lanes_d, active_d,
                    ) = self._static_arrays(
                        ckey, eval_fk, f_list, eval_upos, ncp, nt
                    )
                    parts = [
                        self._tau_partitions(subs[g[0]], caps.tau)
                        for g in ugroups
                    ]
                    pad_part = [self._empty_parts(caps.tau)] * (nup - nu)
                    uniq_spo_sh = jnp.stack(
                        [p[0] for p in parts] + pad_part
                    )
                    uniq_ops_sh = jnp.stack(
                        [p[1] for p in parts] + pad_part
                    )
                    if chain is not None:
                        # membership bits remapped to this cohort's dense
                        # local frontier slots (they key f_map)
                        seg_local = _seg_local_bits(
                            chain.seg, tuple(fs_used)
                        )
                        args = (
                            chain.union,
                            seg_local,
                            a_sets,
                            bank_dev,
                            uniq_taus,
                            uniq_spo_sh,
                            uniq_ops_sh,
                            f_map_d,
                            tgt_map_d,
                            rhos_c,
                            pats_d,
                            lanes_d,
                            active_d,
                        )
                        builder = (
                            lambda nfcp=nfcp: make_sharded_cohort_step(
                                rep.plan, caps, id_cap, self.mesh,
                                axis=self._shard_axis,
                                n_shards=self._n_shards,
                                matcher=self.matcher,
                                delta=True, n_frontiers=nfcp,
                            )
                        )
                    else:
                        args = (
                            d_sets,
                            a_sets,
                            bank_dev,
                            uniq_taus,
                            uniq_spo_sh,
                            uniq_ops_sh,
                            f_map_d,
                            tgt_map_d,
                            rhos_c,
                            pats_d,
                            lanes_d,
                            active_d,
                        )
                        builder = lambda: make_sharded_cohort_step(  # noqa: E731
                            rep.plan, caps, id_cap, self.mesh,
                            axis=self._shard_axis, n_shards=self._n_shards,
                            matcher=self.matcher,
                        )
                elif chain is not None:
                    # delta chain: ONE union store for the whole cohort at
                    # the union's own row bucket u_cap; per-frontier
                    # membership-masked words over the union rows (a row
                    # outside a member's frontier carries zero bits, so
                    # the shared store adds no candidates — no per-frontier
                    # slices, no per-member store gather, and the whole
                    # D-side evaluation runs at distinct-row shapes)
                    d_words = tuple(d_words_all[fi] for fi in fs_used)
                    if nfcp > nfc:
                        zero_w = jnp.zeros((u_cap, n_words_p), jnp.uint32)
                        d_words = d_words + (zero_w,) * (nfcp - nfc)
                    ckey = (
                        "cohort-delta", skey, caps, id_cap, ncp, nup, nfcp,
                        n_words_p, u_cap, mkey, dev,
                    )
                    (
                        f_map_d, tgt_map_d, pats_d, lanes_d, active_d,
                    ) = self._static_arrays(
                        ckey, eval_fk, f_list, eval_upos, ncp, nt,
                        device=device,
                    )
                    args = (
                        chain.union,
                        d_words,
                        a_sets,
                        self._ensure_bank_dev(dev) if placed else bank_dev,
                        uniq_taus,
                        f_map_d,
                        tgt_map_d,
                        rhos_c,
                        pats_d,
                        lanes_d,
                        active_d,
                    )
                    if placed:
                        args = jax.device_put(args, device)
                    builder = lambda: make_cohort_step(  # noqa: E731
                        rep.plan, caps, id_cap, matcher=self.matcher,
                        delta=True,
                    )
                else:
                    d_words = tuple(
                        d_words_all[fi, : caps.n_removed] for fi in fs_used
                    )
                    if nfcp > nfc:
                        zero_w = jnp.zeros(
                            (caps.n_removed, n_words_p), jnp.uint32
                        )
                        d_words = d_words + (zero_w,) * (nfcp - nfc)
                    ckey = (
                        "cohort", skey, caps, id_cap, ncp, nup, nfcp,
                        n_words_p, mkey, dev,
                    )
                    (
                        f_map_d, tgt_map_d, pats_d, lanes_d, active_d,
                    ) = self._static_arrays(
                        ckey, eval_fk, f_list, eval_upos, ncp, nt,
                        device=device,
                    )
                    args = (
                        d_sets,
                        d_words,
                        a_sets,
                        self._ensure_bank_dev(dev) if placed else bank_dev,
                        uniq_taus,
                        f_map_d,
                        tgt_map_d,
                        rhos_c,
                        pats_d,
                        lanes_d,
                        active_d,
                    )
                    if placed:
                        # commit every operand to the cohort's device:
                        # resident state (τ/ρ, statics, bank, padding) is
                        # already there, so only the frontier slices move
                        args = jax.device_put(args, device)
                    builder = lambda: make_cohort_step(  # noqa: E731
                        rep.plan, caps, id_cap, matcher=self.matcher
                    )
                miss = ckey not in self._exec_cache
                fn = self._build_exec(ckey, builder, args)
                if miss:
                    self.cohort_compiles[ckey] = (
                        self.cohort_compiles.get(ckey, 0) + 1
                    )
                tau1_c, rho1_c, out_c = fn(*args)
                n_passes += 1
                if sharded:
                    for i in range(len(self._devices)):
                        self.device_passes[i] = (
                            self.device_passes.get(i, 0) + 1
                        )
                else:
                    self.device_passes[dev or 0] = (
                        self.device_passes.get(dev or 0, 0) + 1
                    )
                for ug, g in enumerate(ugroups):
                    pos0 = members.index(g[0])
                    out = out_c[pos0]
                    if bool(out.overflow):
                        overflowed.extend(g)
                        continue
                    for k in g:  # shared-τ members adopt one state object
                        outs[k] = out
                        staged[k] = (tau1_c[pos0], rho1_c[pos0])

            if overflowed:
                n_retries += 1
                if n_retries > self.max_fire_retries:
                    # bounded degradation: past the ceiling, evaluate the
                    # still-overflowing subscribers through the seed
                    # per-interest path (bit-identical by the oracle
                    # discipline; it doubles only the one subscriber's
                    # caps) instead of re-running the whole multi-frontier
                    # fire while capacities grow without limit
                    degraded = sorted(set(overflowed))
                    for k in degraded:
                        tau1, rho1, out = self._degraded_eval(
                            k, front_of[k], mkey
                        )
                        outs[k] = out
                        staged[k] = (tau1, rho1)
                        n_passes += 1
                    self.degraded_fires += len(degraded)
                    self._degraded_acc += len(degraded)
                    return outs, staged, n_passes
                # grow only the subscribers that overflowed, then re-run the
                # whole fire (staged updates are discarded: atomic commit)
                for k in sorted(set(overflowed)):
                    subs[k].recompile(subs[k].caps.doubled())
                continue
            return outs, staged, n_passes

    def _degraded_eval(
        self, k: int, fr: _FrontierInput, mkey
    ) -> Tuple[TripleStore, TripleStore, EvalOutputs]:
        """Seed-path fallback for one subscriber whose cohort fire kept
        overflowing past ``max_fire_retries``: the per-interest
        :func:`~repro.core.propagation.make_interest_step` evaluation of
        its composed frontier, doubling only its own capacities until the
        outputs fit. Outputs and staged state are bit-identical to the
        cohort path (the same oracle every broker layer is pinned
        against); only throughput degrades."""
        s = self.subs[k]
        while fr.d_rows > s.caps.n_removed or fr.a_rows > s.caps.n_added:
            s.recompile(s.caps.doubled())
        if self.dictionary.id_capacity > s.id_capacity:
            s.recompile()
        for _ in range(64):
            d = fr.d_store(s.caps.n_removed)
            a = fr.a_store(s.caps.n_added)
            key = ("seed", s.serial, s.plan_version, s.caps, mkey)
            fn = self._build_exec(
                key,
                lambda: make_interest_step(
                    s.plan,
                    id_capacity=s.id_capacity,
                    caps=s.caps,
                    matcher=self.matcher,
                ),
                (d, a, s.tau, s.rho),
            )
            tau1, rho1, out = fn(d, a, s.tau, s.rho)
            if not bool(out.overflow):
                return tau1, rho1, out
            s.recompile(s.caps.doubled())
        raise RuntimeError(
            "degraded seed-path fire failed to converge after 64 doublings"
        )

    def _commit_staged(
        self, staged: Dict[int, Tuple[TripleStore, TripleStore]]
    ) -> None:
        """Commit staged (τ', ρ') for the acked subscribers.

        Only the sharded path consults the τ-partition cache, and only
        an actually-changed replica should invalidate it — a fire
        whose changesets missed this interest commits a bit-identical
        τ, and re-partitioning it would waste the exact host round
        trip the cache exists to amortize. Comparisons memoize on the
        (old, new) array pair, so a shared-τ group syncs once.
        """
        subs = self.subs
        sharded = self.mesh is not None and self.shard_cohorts
        unchanged_cache: Dict[Tuple[int, int], bool] = {}
        for k, (tau1, rho1) in staged.items():
            s = subs[k]
            unchanged = False
            if sharded:
                pair = (id(s.tau.spo), id(tau1.spo))
                unchanged = unchanged_cache.get(pair)
                if unchanged is None:
                    unchanged = s.tau.spo.shape == tau1.spo.shape and bool(
                        jnp.all(s.tau.spo == tau1.spo)
                    )
                    unchanged_cache[pair] = unchanged
            if not unchanged:
                s.tau_version += 1
            s.tau, s.rho = tau1, rho1
        if staged:
            # block on every cohort's output so elapsed_s covers all
            # work; lane-group members alias one τ array, so block on
            # each distinct array once, not per delivery
            jax.block_until_ready(
                list({
                    id(tau1.spo): tau1.spo
                    for tau1, _ in staged.values()
                }.values())
            )

    # -- durability: snapshot / recovery / compaction -----------------------

    def snapshot(self, store) -> int:
        """Persist full broker state into a :class:`CheckpointStore`.

        Keyed by the current journal sequence (atomic tmp-dir+rename, see
        ``checkpoint/store.py``), so replay after a restore is bounded to
        the journal tail past this seq — plus the pre-snapshot *ingest*
        records still pending on some subscriber's consumption frontier,
        which is exactly what :meth:`compact_journal` keeps. τ/ρ are saved
        as canonical host row arrays (lex-sorted valid rows), so restoring
        through ``from_array`` reproduces them bit for bit.
        """
        state = {
            "subs": {
                str(s.jid): {
                    "tau": to_numpy(s.tau),
                    "rho": to_numpy(s.rho),
                }
                for s in self.subs
            }
        }
        extra = {
            "seq": self._seq,
            "jid_next": self._jid_next,
            "last_cid": self._last_cid,
            "subs": [
                {
                    "jid": s.jid,
                    "expr": _expr_to_json(s.expr),
                    "caps": _caps_to_json(s.caps),
                    "policy": _policy_to_json(s.policy),
                    "since": s.since,
                }
                for s in self.subs
            ],
        }
        store.save(self._seq, state, extra)
        self._last_snapshot_seq = self._seq
        self._snapshot_keep_from = min(
            [s.since for s in self.subs] + [self._seq + 1]
        )
        return self._seq

    def compact_journal(self) -> int:
        """Drop journal segments replay can never need; returns segments
        removed. Safe exactly when a snapshot exists: replay needs (a)
        records after the last snapshot and (b) ingest records at or after
        the snapshot's oldest live consumption frontier — without a
        snapshot everything from seq 1 is needed, so nothing is dropped.
        """
        if self.journal is None:
            return 0
        return self.journal.compact(self._snapshot_keep_from)

    @classmethod
    def recover(
        cls,
        journal: ChangesetJournal,
        store=None,
        dictionary: Dictionary | None = None,
        **broker_kwargs,
    ) -> "Broker":
        """Rebuild a broker from its journal (+ optional snapshot store).

        Picks the newest snapshot whose seq is <= the journal's durable
        ``last_seq`` (a snapshot ahead of the durable prefix reflects
        un-journaled state and is skipped), restores every subscription's
        τ/ρ/frontier from it, then replays the journal tail: pre-snapshot
        *ingest* records rebuild the pending :class:`ChangesetBatch`es
        (self-gating — only changesets at or past a restored frontier
        land in a batch), and post-snapshot records re-run their original
        operations with journaling and delivery suppressed. Fires replay
        exactly the recorded acked subscribers, so a delivery that failed
        before the crash stays un-committed after recovery. The result is
        bit-identical broker state: same τ/ρ rows, same frontiers, same
        pending batches, same sequence clock.

        ``dictionary`` must be the same dictionary the crashed broker
        encoded with (term↔id growth happens in the caller and is not
        journaled). Per-subscriber transports and channel retry state are
        ephemeral — re-attach transports after recovery; quarantine is
        re-earned. Lane-group/share lineage of *restored* subscriptions is
        not reconstructed (a missed collapse only — values stay
        bit-identical); subscriptions replayed from post-snapshot records
        rebuild their lineage normally.
        """
        broker = cls(
            dictionary=dictionary, journal=journal, **broker_kwargs
        )
        broker._seq = 0
        snap_step = 0
        extra: Dict = {}
        if store is not None:
            usable = [s for s in store.steps() if s <= journal.last_seq]
            if usable:
                snap_step = usable[-1]
                arrays, extra = store.load_raw(snap_step)
                broker._replaying = True
                try:
                    for meta in extra["subs"]:
                        broker._restore_sub(meta, arrays)
                finally:
                    broker._replaying = False
                broker._seq = int(extra["seq"])
                broker._jid_next = int(extra["jid_next"])
                broker._last_snapshot_seq = snap_step
        min_since = min(
            [s.since for s in broker.subs] + [snap_step + 1]
        )
        records = list(journal.records())
        if records and records[0].seq > min(min_since, snap_step + 1):
            raise RuntimeError(
                f"journal starts at seq {records[0].seq} but replay needs "
                f"seq {min(min_since, snap_step + 1)}: a needed segment "
                "was compacted away or lost"
            )
        broker._replaying = True
        try:
            for rec in records:
                if rec.seq <= snap_step:
                    # pre-snapshot: only ingests still pending on some
                    # restored frontier matter; everything else is already
                    # reflected in the snapshot
                    if rec.kind == "ingest" and rec.seq >= min_since:
                        broker._apply_ingest(
                            rec.arrays["removed"], rec.arrays["added"],
                            rec.seq,
                        )
                    continue
                broker._seq = rec.seq - 1
                if rec.kind == "ingest":
                    broker._seq = rec.seq
                    broker._apply_ingest(
                        rec.arrays["removed"], rec.arrays["added"], rec.seq
                    )
                elif rec.kind == "subscribe":
                    broker.subscribe(
                        _expr_from_json(rec.meta["expr"]),
                        caps=_caps_from_json(rec.meta["caps"]),
                        initial_target=rec.arrays.get("initial_target"),
                        policy=_policy_from_json(rec.meta["policy"]),
                        share_target=bool(rec.meta["share_target"]),
                        _jid=int(rec.meta["jid"]),
                    )
                elif rec.kind == "unsubscribe":
                    broker.unsubscribe(
                        broker._sub_by_jid(int(rec.meta["jid"]))
                    )
                elif rec.kind == "fire":
                    broker._replay_fire(rec)
                else:
                    raise RuntimeError(
                        f"unknown journal record kind {rec.kind!r}"
                    )
        finally:
            broker._replaying = False
        if extra:
            broker._last_cid = max(
                broker._last_cid, int(extra["last_cid"])
            )
        broker._seq = max(broker._seq, journal.last_seq)
        broker._sweep_batches(drained=False)
        return broker

    def _restore_sub(self, meta: Dict, arrays: Dict) -> None:
        """One snapshot subscription back to life (no journaling)."""
        sub = BrokerSubscription(
            _expr_from_json(meta["expr"]),
            self.dictionary,
            _caps_from_json(meta["caps"]),
            policy=_policy_from_json(meta["policy"]),
        )
        sub.jid = int(meta["jid"])
        sub.since = int(meta["since"])
        prefix = f"subs/{sub.jid}/"
        tau_rows = arrays[prefix + "tau"]
        rho_rows = arrays[prefix + "rho"]
        if tau_rows.size:
            sub.tau, _ = from_array(
                jnp.asarray(tau_rows, jnp.int32), sub.caps.tau
            )
        if rho_rows.size:
            sub.rho, _ = from_array(
                jnp.asarray(rho_rows, jnp.int32), sub.caps.rho
            )
        sub.lanes = self.bank.add_plan(sub.plan)
        self.subs.append(sub)
        self._lanes_raw += sub.plan.n_total

    def _sub_by_jid(self, jid: int) -> BrokerSubscription:
        for s in self.subs:
            if s.jid == jid:
                return s
        raise RuntimeError(f"journal references unknown subscriber {jid}")

    def _replay_fire(self, rec) -> None:
        """Re-run one committed fire for exactly the recorded subscribers.

        Re-evaluates the recorded frontiers (delivery suppressed — the
        receivers already have these outputs; a re-send would be harmless
        anyway, see the Def-6 idempotence contract in the module
        docstring) and commits their staged τ/ρ and frontier advances.
        The recorded ``new_since`` values double as an integrity check.
        """
        by_jid = {int(j): int(ns) for j, ns in rec.meta["fires"]}
        ks = [
            k for k, s in enumerate(self.subs) if s.jid in by_jid
        ]
        if len(ks) != len(by_jid):
            missing = set(by_jid) - {self.subs[k].jid for k in ks}
            raise RuntimeError(
                f"fire record {rec.seq} references unknown "
                f"subscribers {sorted(missing)}"
            )
        self._fire(ks)
        for k in ks:
            s = self.subs[k]
            if s.since != by_jid[s.jid]:
                raise RuntimeError(
                    f"replayed fire {rec.seq} advanced subscriber "
                    f"{s.jid} to {s.since}, journal recorded "
                    f"{by_jid[s.jid]}"
                )

    # -- accounting ---------------------------------------------------------

    def _record_stats(
        self,
        changeset_id: int,
        removed: np.ndarray,
        added: np.ndarray,
        results: List[Optional[EvalOutputs]],
        fired: List[int],
        n_passes: int,
        t0: float,
    ) -> None:
        # fanned-out deliveries share one EvalOutputs per lane group: fetch
        # each distinct result once and weight by its member count, so stats
        # stay O(distinct interests) host syncs per call. A fired subscriber
        # whose delivery failed has no committed result (None): its work is
        # counted when the retry eventually acks.
        uniq: Dict[int, Tuple[EvalOutputs, int]] = {}
        for k in fired:
            o = results[k]
            if o is None:
                continue
            ent = uniq.get(id(o))
            uniq[id(o)] = (o, 1 if ent is None else ent[1] + 1)
        self.stats.append(
            BrokerStats(
                changeset_id=changeset_id,
                n_subscribers=len(self.subs),
                n_lanes=self.bank.n_lanes,
                n_lanes_raw=self._lanes_raw,
                total_removed=int(removed.shape[0]),
                total_added=int(added.shape[0]),
                interesting_removed=sum(
                    int(o.r.n) * c for o, c in uniq.values()
                ),
                interesting_added=sum(
                    int(o.a.n) * c for o, c in uniq.values()
                ),
                elapsed_s=time.perf_counter() - t0,
                rejit_s=self._rejit_acc,
                n_evaluated=len(fired),
                n_deferred=len(self.subs) - len(fired),
                n_cohort_passes=n_passes,
                batch_grows=self.batch_grows,
                batch_shrinks=self.batch_shrinks,
                rows_matched=self._rows_matched_acc,
                rows_distinct=self._rows_distinct_acc,
                distinct_interests=self._distinct_acc,
                fanout_copies=self._fanout_acc,
                seq=self._seq,
                degraded_fires=self._degraded_acc,
            )
        )
