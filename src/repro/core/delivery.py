"""Delivery robustness tier between ``Broker._fire`` and subscribers.

A :class:`DeliveryChannel` sits at the broker's commit point: a fired
subscriber's :class:`~repro.core.propagation.EvalOutputs` are handed to a
transport callback *before* the broker advances that subscriber's
consumption frontier or commits its τ/ρ, so a failed delivery simply
leaves the subscriber un-committed — its pending
:class:`~repro.core.propagation.ChangesetBatch` keeps composing (Def-6)
and the next eligible fire re-delivers the *composed* window. Composition
makes that retry idempotent for the receiver (see the broker module
docstring's durability contract), so the channel only has to provide
at-least-once delivery with bounded, deterministic failure handling:

* **retry + exponential backoff with jitter** — each failed delivery
  schedules the subscriber's next attempt at ``base_backoff_s *
  backoff_factor**(failures-1)`` seconds (capped at ``max_backoff_s``),
  scaled by a seeded jitter factor so retries are reproducible under a
  fake clock yet de-synchronized in production;
* **timeout** — a transport call that raises *or* takes longer than
  ``timeout_s`` (measured on the injected clock, so fakes can simulate
  slow transports) counts as a failed delivery;
* **poison quarantine** — after ``quarantine_after`` consecutive failed
  deliveries the subscriber is quarantined: excluded from fires entirely
  (its frontier pins, its batch keeps composing under its capacity cap)
  until :meth:`readmit`, so one poisonous consumer cannot stall the
  broker or burn retry work forever;
* **bounded in-flight queue** — subscribers awaiting retry count as
  in-flight; when ``max_in_flight`` is reached the broker's ingest path
  backpressures (``Broker._service_channel``): it sleeps to the next
  retry deadline and pumps retries until each in-flight subscriber either
  acks or progresses to quarantine, both of which shrink the queue — so
  the pump terminates and ingest never deadlocks.

``clock`` / ``sleep`` / the jitter RNG are injectable, which is what makes
the fault-injection harness (:mod:`repro.testing.faults`) fully
deterministic: goldens pin exact backoff schedules against a fake clock.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Dict, Optional

Transport = Callable[[object, object], object]


@dataclasses.dataclass
class DeliveryStats:
    """Cumulative channel accounting."""

    attempts: int = 0  # transport invocations (incl. in-call retries)
    successes: int = 0  # delivered fires
    failures: int = 0  # failed deliveries (all in-call attempts exhausted)
    timeouts: int = 0  # attempts that exceeded timeout_s
    quarantines: int = 0  # subscribers moved to quarantine


@dataclasses.dataclass
class _SubState:
    failures: int = 0  # consecutive failed deliveries
    next_retry: float = 0.0
    quarantined: bool = False


class DeliveryChannel:
    """Per-subscriber retry/backoff/timeout/quarantine around a transport.

    ``transport(sub, outputs)`` is the channel-level default delivery
    callback; a subscriber with its own ``sub.transport`` overrides it.
    With neither, delivery trivially succeeds (the channel is then pure
    bookkeeping). Raising — or exceeding ``timeout_s`` on the injected
    clock — marks the attempt failed.
    """

    def __init__(
        self,
        transport: Optional[Transport] = None,
        *,
        max_attempts: int = 3,
        base_backoff_s: float = 0.05,
        backoff_factor: float = 2.0,
        max_backoff_s: float = 5.0,
        jitter: float = 0.1,
        timeout_s: Optional[float] = None,
        quarantine_after: int = 5,
        max_in_flight: Optional[int] = 64,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        seed: int = 0,
    ):
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        self.transport = transport
        self.max_attempts = max_attempts
        self.base_backoff_s = base_backoff_s
        self.backoff_factor = backoff_factor
        self.max_backoff_s = max_backoff_s
        self.jitter = jitter
        self.timeout_s = timeout_s
        self.quarantine_after = quarantine_after
        self.max_in_flight = max_in_flight
        self.clock = clock
        self.sleep = sleep
        self.stats = DeliveryStats()
        self._rng = random.Random(seed)
        self._state: Dict[int, _SubState] = {}  # sub.serial -> state

    # -- schedule queries (used by the broker's fire selection) -------------

    def eligible(self, sub) -> bool:
        """May this subscriber fire now? (not quarantined, backoff elapsed)"""
        st = self._state.get(sub.serial)
        if st is None:
            return True
        if st.quarantined:
            return False
        return self.clock() >= st.next_retry

    def retry_due(self, sub) -> bool:
        """Has a *failed* subscriber's backoff elapsed?"""
        st = self._state.get(sub.serial)
        return (
            st is not None
            and not st.quarantined
            and self.clock() >= st.next_retry
        )

    def is_quarantined(self, sub) -> bool:
        st = self._state.get(sub.serial)
        return st is not None and st.quarantined

    def failures(self, sub) -> int:
        st = self._state.get(sub.serial)
        return 0 if st is None else st.failures

    def next_retry_at(self, sub) -> Optional[float]:
        st = self._state.get(sub.serial)
        if st is None or st.quarantined:
            return None
        return st.next_retry

    def in_flight(self) -> int:
        """Subscribers with a failed delivery awaiting retry (not poison)."""
        return sum(1 for st in self._state.values() if not st.quarantined)

    def readmit(self, sub) -> None:
        """Clear a subscriber's failure/quarantine state; it may fire again."""
        self._state.pop(sub.serial, None)

    def forget(self, sub) -> None:
        self._state.pop(sub.serial, None)

    def wait_for_retry(self) -> None:
        """Sleep (injected) until the earliest pending retry deadline."""
        deadlines = [
            st.next_retry
            for st in self._state.values()
            if not st.quarantined
        ]
        if not deadlines:
            return
        dt = min(deadlines) - self.clock()
        if dt > 0:
            self.sleep(dt)

    # -- delivery -----------------------------------------------------------

    def _backoff(self, failures: int) -> float:
        base = min(
            self.max_backoff_s,
            self.base_backoff_s * self.backoff_factor ** max(0, failures - 1),
        )
        return base * (1.0 + self.jitter * self._rng.random())

    def _attempt(self, fn: Transport, sub, outputs) -> bool:
        self.stats.attempts += 1
        t0 = self.clock()
        try:
            fn(sub, outputs)
        except Exception:
            return False
        if (
            self.timeout_s is not None
            and self.clock() - t0 > self.timeout_s
        ):
            self.stats.timeouts += 1
            return False
        return True

    def deliver(self, sub, outputs) -> bool:
        """One delivery: up to ``max_attempts`` transport calls with in-call
        backoff. True advances the subscriber (the broker commits); False
        leaves it pinned with its retry schedule updated."""
        fn = getattr(sub, "transport", None) or self.transport
        ok = True
        if fn is not None:
            for attempt in range(self.max_attempts):
                ok = self._attempt(fn, sub, outputs)
                if ok:
                    break
                if attempt + 1 < self.max_attempts:
                    self.sleep(self._backoff(attempt + 1))
        if ok:
            self.stats.successes += 1
            self._state.pop(sub.serial, None)
            return True
        self.stats.failures += 1
        st = self._state.setdefault(sub.serial, _SubState())
        st.failures += 1
        if st.failures >= self.quarantine_after:
            st.quarantined = True
            self.stats.quarantines += 1
        else:
            st.next_retry = self.clock() + self._backoff(st.failures)
        return False
