"""Interest evaluation over changesets (Definitions 11-15, DESIGN.md §1-2).

The evaluator is built per ``CompiledInterest`` by :func:`make_side_evaluator`
— a factory closing over the static plan — and classifies one side of a
changeset (the removed set D, or I = A ∪ ρ for the added side) into

  * interesting triples  (full BGP match over M ∪ τ with >= 1 triple from M),
  * potentially interesting triples (partial match),
  * pulls — the π' candidate-assertion retrievals from the target dataset τ
    (missing BGP patterns + OGP patterns of full bindings; these are r' for
    the delete side and the τ-completion part of `a` for the add side).

Dataflow (all fixed-shape, jit-compiled):
  1. pattern bitset over M            (triple_match kernel / XLA fallback)
  2. generation signature table       (scatter bits per binding  — π, Def 11)
  3. candidate pools + τ probes       (blocked sort-merge probes — π', Def 12)
  4. tree semijoin gating             (child_ok / edge_ok / full / linked_full)
  5. per-triple classification + fixed-capacity compaction
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .interest import CompiledInterest
from .triples import (
    PAD,
    TripleStore,
    compact,
    from_array,
    lex_sort,
    prefix_range,
)


@partial(jax.tree_util.register_dataclass, data_fields=["spo", "ops"], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class TripleIndex:
    """Two sort orders over the same triple set (the SPO / OPS indexes)."""

    spo: TripleStore  # rows (s, p, o), lex-sorted
    ops: TripleStore  # rows permuted to (o, p, s), lex-sorted in that order


def build_index(store: TripleStore) -> TripleIndex:
    ops_rows = lex_sort(store.spo[:, jnp.array([2, 1, 0])])
    return TripleIndex(spo=store, ops=TripleStore(spo=ops_rows, n=store.n))


# ---------------------------------------------------------------------------
# cohort pytree helpers (the broker's stacked/batched evaluation plumbing)
# ---------------------------------------------------------------------------

def tree_stack(trees):
    """Stack identical pytrees along a new leading (cohort-member) axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def tree_index(tree, i):
    """Slice one member out of a leading-axis-stacked pytree."""
    return jax.tree.map(lambda x: x[i], tree)


def tree_gather(tree, idx: jax.Array):
    """Gather members of a stacked pytree by a (traced) index vector.

    Used by the broker's shared-τ path: target indexes are built once per
    *unique* target dataset and fanned out to every cohort member via this
    gather, so K subscribers of one replica pay for one ``build_index``.
    """
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), tree)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["interesting", "potential", "pulls", "overflow"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class SideResult:
    interesting: TripleStore
    potential: TripleStore
    pulls: TripleStore
    overflow: jax.Array  # bool — any output capacity exceeded


# ---------------------------------------------------------------------------
# target-dataset probe (candidate assertion primitive)
# ---------------------------------------------------------------------------

def probe(
    index: TripleIndex,
    pattern: np.ndarray,  # (3,) int32 host constants, -1 for variable slots
    bound_slot: int,
    bound_vals: jax.Array,  # int32[B]; PAD entries are masked out
    fanout: int,
) -> Tuple[jax.Array, jax.Array]:
    """Retrieve up to ``fanout`` τ rows matching ``pattern`` with one slot bound.

    Returns (rows int32[B, K, 3] in (s, p, o) order, valid bool[B, K]).
    Probes use the SPO index for subject-bound patterns and the OPS index for
    object-bound ones; non-prefix constant slots are post-filtered.
    """
    return probe_dyn(
        index,
        pattern,
        jnp.asarray(pattern, jnp.int32),
        bound_slot,
        bound_vals,
        fanout,
    )


def probe_dyn(
    index: TripleIndex,
    pattern_host: np.ndarray,  # (3,) int32 host row — static const/var structure
    pattern_dev: jax.Array,  # (3,) int32 traced row — comparison values
    bound_slot: int,
    bound_vals: jax.Array,
    fanout: int,
) -> Tuple[jax.Array, jax.Array]:
    """:func:`probe` with traced pattern *values* and static structure.

    The broker's batched (vmapped) path evaluates whole cohorts of
    same-shape interests at once, so the constant slots' values must be
    traced operands (they differ per subscriber) while which slots are
    constant — probe depth, index choice, post-filter set — stays static
    (identical across the cohort by construction). Produces exactly the
    values of :func:`probe` for equal inputs.
    """
    if bound_slot == 1:
        raise ValueError("predicate-bound probes are unsupported (compile-time)")
    const = [int(pattern_host[k]) >= 0 for k in range(3)]
    vals = [pattern_dev[k] for k in range(3)]
    if bound_slot == 0:
        store = index.spo
        (c1_const, c1_val), (c2_const, c2_val) = (
            (const[1], vals[1]),
            (const[2], vals[2]),
        )
    else:
        store = index.ops
        (c1_const, c1_val), (c2_const, c2_val) = (
            (const[1], vals[1]),
            (const[0], vals[0]),
        )
    depth = 1 + (1 if c1_const else 0) + (1 if (c1_const and c2_const) else 0)

    b = bound_vals.shape[0]
    cap = store.capacity
    zero = jnp.zeros((), jnp.int32)
    prefix = jnp.stack(
        [
            bound_vals,
            jnp.broadcast_to(c1_val if c1_const else zero, (b,)),
            jnp.broadcast_to(c2_val if c2_const else zero, (b,)),
        ],
        axis=1,
    )
    start, end = prefix_range(store, prefix, jnp.full((b,), depth, jnp.int32))
    offs = jnp.arange(fanout, dtype=jnp.int32)
    idx = start[:, None] + offs[None, :]
    rows = jnp.take(store.spo, jnp.clip(idx, 0, cap - 1), axis=0)
    valid = (idx < end[:, None]) & (bound_vals != PAD)[:, None]
    if bound_slot == 2:
        rows = rows[..., jnp.array([2, 1, 0])]
    for k in range(3):
        if const[k]:
            valid = valid & (rows[..., k] == vals[k])
    valid = valid & (rows[..., bound_slot] == bound_vals[:, None])
    return rows, valid


# ---------------------------------------------------------------------------
# side evaluator factory
# ---------------------------------------------------------------------------

def make_side_evaluator(
    plan: CompiledInterest,
    *,
    id_capacity: int,
    fanout: int = 4,
    out_capacity: int,
    pull_capacity: int,
    matcher: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
    probe_impl: Callable | None = None,
    table_reduce: Callable[[jax.Array], jax.Array] | None = None,
    dedup_candidates: int = 0,
    dynamic_patterns: bool = False,
) -> Callable[[TripleStore, TripleIndex], SideResult]:
    """Build the jitted one-side evaluator for a compiled interest.

    ``probe_impl``/``table_reduce`` are the distribution hooks
    (core/distributed.py): the sharded evaluator swaps in an all_to_all
    routed probe and an OR-all-reduce over the signature tables; the local
    evaluator uses :func:`probe` / :func:`probe_dyn` and identity.

    ``dynamic_patterns=True`` builds the evaluator for the broker's batched
    cohort path: the returned callable takes the pattern *values* as a
    traced ``patterns`` argument so a whole cohort of same-shape interests
    can be vmapped; ``plan`` then only supplies the static structure (kinds,
    slots, const masks).  The hooks compose with it — the broker's sharded
    cohort step routes cohort probes across the mesh — but the probe hook
    contract changes with the mode, because the pattern constants are traced
    per member:

      static  (default)          ``probe_impl(index, pattern, bound_slot,
                                 bound_vals, fanout)`` — :func:`probe`-shaped,
                                 e.g. ``distributed.make_routed_probe``;
      dynamic (``dynamic_patterns=True``)
                                 ``probe_impl(index, pattern_host,
                                 pattern_dev, bound_slot, bound_vals,
                                 fanout)`` — :func:`probe_dyn`-shaped, e.g.
                                 ``distributed.make_routed_probe_batched``.

    ``table_reduce`` sees boolean signature tables in both modes and must
    batch under ``jax.vmap`` when the cohort path is in play
    (``distributed.make_or_reduce`` does).

    **Refined-lane contract (subsumption lattice).** The evaluator never
    inspects how its per-row lane bits were produced: the broker may hand
    it bits from a *virtual* bank lane — a parent row's word ANDed with a
    residual predicate by ``kernels.ops.lane_refine`` instead of a
    materialized bank row. That substitution is sound only under the
    invariant ``interest.SubsumptionBank`` maintains: the residual binds
    exactly the slots where the parent row has a variable, so
    ``parent AND residual`` equals the bits a materialized child row
    would produce, and everything downstream (candidate extraction,
    probes, output construction) is bit-identical by construction.
    """
    matcher = matcher or kops.pattern_bitmask
    probe_dyn_impl = (probe_impl or probe_dyn) if dynamic_patterns else None
    probe_impl = probe_impl or probe
    table_reduce = table_reduce or (lambda t: t)
    dedup_cap = dedup_candidates

    def maybe_dedup(vec: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Sort-unique a candidate vector to ``dedup_cap`` slots (§Perf HC-C).

        The paper-faithful baseline probes one τ lookup per (M row x
        pattern); bindings repeat heavily (every triple of an entity yields
        the same binding), so deduplicating before the probe collapses the
        probe pool by the mean entity degree. Returns (vec', overflowed).
        """
        if not dedup_cap:
            return vec, jnp.zeros((), bool)
        s = jnp.sort(vec)
        first = jnp.concatenate(
            [jnp.ones((1,), bool), s[1:] != s[:-1]]
        ) & (s != PAD)
        order = jnp.argsort(jnp.logical_not(first), stable=True)
        uniq = s[order]
        count = jnp.sum(first)
        idx = jnp.arange(s.shape[0], dtype=jnp.int32)
        uniq = jnp.where(idx < count, uniq, PAD)
        return uniq[:dedup_cap], count > dedup_cap
    R = id_capacity
    K = fanout
    nt = plan.n_total
    patterns_dev = jnp.asarray(plan.patterns)
    kinds = plan.kinds
    anchor = plan.anchor_slot
    cslot = plan.child_slot
    cvar = plan.child_var
    n_children = plan.n_children

    root_js = [j for j in range(nt) if kinds[j] == "root"]
    edge_js = [j for j in range(nt) if kinds[j] == "edge"]
    child_js = [j for j in range(nt) if kinds[j] == "child"]
    bgp_root_js = [j for j in root_js if j < plan.n_bgp]
    bgp_edge_js = [j for j in edge_js if j < plan.n_bgp]
    child_bgp_stars = {
        cv: [j for j in child_js if cvar[j] == cv and j < plan.n_bgp]
        for cv in range(n_children)
    }
    child_all_stars = {
        cv: [j for j in child_js if cvar[j] == cv] for cv in range(n_children)
    }
    edges_of = {
        cv: [e for e in edge_js if cvar[e] == cv] for cv in range(n_children)
    }

    def evaluate(
        m: TripleStore,
        tgt: TripleIndex,
        bits: jax.Array | None = None,
        patterns: jax.Array | None = None,
    ) -> SideResult:
        """Classify one changeset side.

        ``bits`` (optional) is a precomputed uint32[N] pattern bitset in this
        plan's local numbering — the broker's fused path computes one bank
        bitset per changeset side and routes lanes here, skipping the
        per-interest matcher pass. Must equal ``matcher(m.spo, patterns)``.

        ``patterns`` (dynamic_patterns mode only) carries the traced
        (n_total, 3) pattern values for this cohort member.
        """
        pats = patterns if patterns is not None else patterns_dev

        def run_probe(j: int, bound_slot: int, bound_vals: jax.Array):
            if dynamic_patterns:
                return probe_dyn_impl(
                    tgt, plan.patterns[j], pats[j], bound_slot, bound_vals, K
                )
            return probe_impl(tgt, plan.patterns[j], bound_slot, bound_vals, K)

        spo = m.spo
        n = m.capacity
        valid_row = spo[:, 0] != PAD
        if bits is None:
            bits = matcher(spo, pats)
        # repeated-variable-in-pattern equality constraints
        for j, eq in enumerate(plan.eq_pairs):
            if eq is not None:
                ok = spo[:, eq[0]] == spo[:, eq[1]]
                bits = jnp.where(ok, bits, bits & np.uint32(~(1 << j) & 0xFFFFFFFF))

        def bit(j: int) -> jax.Array:
            return ((bits >> j) & 1).astype(bool)

        # -- generation signature table (π) --------------------------------
        sat_gen = jnp.zeros((R, nt), dtype=bool)
        for j in root_js + child_js:
            b = spo[:, anchor[j]]
            idx = jnp.where(bit(j), b, R)  # out-of-range -> dropped
            sat_gen = sat_gen.at[idx, j].max(True, mode="drop")

        sat_gen = table_reduce(sat_gen)

        # -- candidate pools + upward edge discovery -----------------------
        # edge pools: per edge, lists of (b, c, valid, rows, is_pull)
        edge_pool: Dict[int, List[Tuple]] = {e: [] for e in edge_js}
        root_cand_parts = [
            jnp.where(bit(j), spo[:, anchor[j]], PAD) for j in root_js
        ]
        for e in edge_js:
            root_cand_parts.append(jnp.where(bit(e), spo[:, anchor[e]], PAD))
            # M edge rows (not pulls)
            edge_pool[e].append(
                (spo[:, anchor[e]], spo[:, cslot[e]], bit(e), spo, False)
            )
            # upward probes: child-star M bindings -> τ edge rows -> roots
            for j in child_all_stars[cvar[e]]:
                c_vec = jnp.where(bit(j), spo[:, anchor[j]], PAD)
                rows, val = run_probe(e, cslot[e], c_vec)
                rows_f = rows.reshape(-1, 3)
                val_f = val.reshape(-1)
                b_f = rows_f[:, anchor[e]]
                c_f = rows_f[:, cslot[e]]
                edge_pool[e].append((b_f, c_f, val_f, rows_f, True))
                root_cand_parts.append(jnp.where(val_f, b_f, PAD))
        root_cand = (
            jnp.concatenate(root_cand_parts)
            if root_cand_parts
            else jnp.full((n,), PAD, jnp.int32)
        )
        root_cand, ovf_d1 = maybe_dedup(root_cand)

        # -- downward edge probes (per edge, for every root candidate) -----
        for e in edge_js:
            rows, val = run_probe(e, anchor[e], root_cand)
            rows_f = rows.reshape(-1, 3)
            val_f = val.reshape(-1)
            edge_pool[e].append(
                (rows_f[:, anchor[e]], rows_f[:, cslot[e]], val_f, rows_f, True)
            )

        # -- child candidate pools ------------------------------------------
        child_cand: Dict[int, jax.Array] = {}
        for cv in range(n_children):
            parts = [
                jnp.where(bit(j), spo[:, anchor[j]], PAD)
                for j in child_all_stars[cv]
            ]
            for e in edges_of[cv]:
                for b_f, c_f, val_f, rows_f, is_pull in edge_pool[e]:
                    parts.append(jnp.where(val_f, c_f, PAD))
            cc, ovf_dc = maybe_dedup(jnp.concatenate(parts))
            child_cand[cv] = cc
            ovf_d1 = ovf_d1 | ovf_dc

        # -- assertion probes (π') -----------------------------------------
        sat_tgt = jnp.zeros((R, nt), dtype=bool)
        pull_entries = []  # (kind, j, cv, bound, rows, valid)
        for j in child_js:
            cv = cvar[j]
            bound = child_cand[cv]
            rows, val = run_probe(j, anchor[j], bound)
            pull_entries.append(("child", j, cv, bound, rows, val))
            found = jnp.any(val, axis=1)
            sat_tgt = sat_tgt.at[jnp.where(found, bound, R), j].max(
                True, mode="drop"
            )
        for j in root_js:
            rows, val = run_probe(j, anchor[j], root_cand)
            pull_entries.append(("root", j, -1, root_cand, rows, val))
            found = jnp.any(val, axis=1)
            sat_tgt = sat_tgt.at[jnp.where(found, root_cand, R), j].max(
                True, mode="drop"
            )

        sat = sat_gen | table_reduce(sat_tgt)

        # -- tree gating -----------------------------------------------------
        child_ok: Dict[int, jax.Array] = {}
        for cv in range(n_children):
            ok = jnp.ones((R,), dtype=bool)
            for j in child_bgp_stars[cv]:
                ok = ok & sat[:, j]
            child_ok[cv] = ok

        def gather_bool(vec: jax.Array, idx: jax.Array) -> jax.Array:
            return jnp.take(vec, idx, mode="fill", fill_value=False)

        edge_ok: Dict[int, jax.Array] = {}
        for e in edge_js:
            acc = jnp.zeros((R,), dtype=bool)
            for b_f, c_f, val_f, rows_f, is_pull in edge_pool[e]:
                v = val_f & gather_bool(child_ok[cvar[e]], c_f)
                acc = acc.at[jnp.where(v, b_f, R)].max(True, mode="drop")
            edge_ok[e] = table_reduce(acc)

        full = jnp.ones((R,), dtype=bool)
        for j in bgp_root_js:
            full = full & sat[:, j]
        for e in bgp_edge_js:
            full = full & edge_ok[e]
        # only bindings seeded by this changeset can be candidates; ids that
        # never appear keep full=AND(...)=True only if nt==0 — guard:
        if not bgp_root_js and not bgp_edge_js:
            full = jnp.zeros((R,), dtype=bool)

        linked_full: Dict[int, jax.Array] = {}
        for cv in range(n_children):
            acc = jnp.zeros((R,), dtype=bool)
            for e in edges_of[cv]:
                for b_f, c_f, val_f, rows_f, is_pull in edge_pool[e]:
                    v = val_f & gather_bool(full, b_f)
                    acc = acc.at[jnp.where(v, c_f, R)].max(True, mode="drop")
            linked_full[cv] = table_reduce(acc)

        # -- per-triple classification (Defs 8-10) ---------------------------
        inter = jnp.zeros((n,), dtype=bool)
        for j in range(nt):
            bj = bit(j)
            if kinds[j] == "root":
                g = gather_bool(full, spo[:, anchor[j]])
            elif kinds[j] == "edge":
                g = gather_bool(full, spo[:, anchor[j]]) & gather_bool(
                    child_ok[cvar[j]], spo[:, cslot[j]]
                )
            else:
                c = spo[:, anchor[j]]
                g = gather_bool(child_ok[cvar[j]], c) & gather_bool(
                    linked_full[cvar[j]], c
                )
            inter = inter | (bj & g)
        potential = valid_row & (bits != 0) & ~inter

        # -- pull inclusion (π' outputs) --------------------------------------
        pull_rows_parts = []
        pull_mask_parts = []
        for kind, j, cv, bound, rows, val in pull_entries:
            gen_bit_at = jnp.take(
                sat_gen[:, j], bound, mode="fill", fill_value=False
            )
            if kind == "root":
                gate = gather_bool(full, bound) & ~gen_bit_at
            else:
                gate = (
                    gather_bool(child_ok[cv], bound)
                    & gather_bool(linked_full[cv], bound)
                    & ~gen_bit_at
                )
            inc = val & gate[:, None]
            pull_rows_parts.append(rows.reshape(-1, 3))
            pull_mask_parts.append(inc.reshape(-1))
        for e in edge_js:
            for b_f, c_f, val_f, rows_f, is_pull in edge_pool[e]:
                if not is_pull:
                    continue
                inc = (
                    val_f
                    & gather_bool(full, b_f)
                    & gather_bool(child_ok[cvar[e]], c_f)
                )
                pull_rows_parts.append(rows_f)
                pull_mask_parts.append(inc)

        if pull_rows_parts:
            pr = jnp.concatenate(pull_rows_parts, axis=0)
            pm = jnp.concatenate(pull_mask_parts, axis=0)
            pr = jnp.where(pm[:, None], pr, PAD)
        else:
            pr = jnp.full((1, 3), PAD, jnp.int32)
        pulls, ovf_p = from_array(pr, pull_capacity)

        inter_rows = jnp.where(inter[:, None], spo, PAD)
        pot_rows = jnp.where(potential[:, None], spo, PAD)
        inter_store, ovf_i = from_array(inter_rows, out_capacity)
        pot_store, ovf_q = from_array(pot_rows, out_capacity)

        return SideResult(
            interesting=inter_store,
            potential=pot_store,
            pulls=pulls,
            overflow=ovf_p | ovf_i | ovf_q | ovf_d1,
        )

    return evaluate
