"""Host-side RDF term dictionary (IRI/literal string <-> dense int32 id).

Dense ids keep signature tables dense (DESIGN.md §2). The dictionary is a
host-side object — device code only ever sees int32 ids.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import numpy as np


class Dictionary:
    """Bidirectional term <-> id map with dense, append-only ids."""

    def __init__(self, capacity_hint: int = 1024):
        self._term_to_id: dict[str, int] = {}
        self._id_to_term: List[str] = []
        self.capacity_hint = capacity_hint

    def __len__(self) -> int:
        return len(self._id_to_term)

    def encode_term(self, term: str) -> int:
        tid = self._term_to_id.get(term)
        if tid is None:
            tid = len(self._id_to_term)
            self._term_to_id[term] = tid
            self._id_to_term.append(term)
        return tid

    def lookup(self, term: str) -> int | None:
        return self._term_to_id.get(term)

    def decode(self, tid: int) -> str:
        return self._id_to_term[tid]

    def encode_triples(self, triples: Iterable[Tuple[str, str, str]]) -> np.ndarray:
        rows = [
            (self.encode_term(s), self.encode_term(p), self.encode_term(o))
            for s, p, o in triples
        ]
        if not rows:
            return np.zeros((0, 3), dtype=np.int32)
        return np.asarray(rows, dtype=np.int32)

    def decode_triples(self, spo: np.ndarray) -> List[Tuple[str, str, str]]:
        return [
            (self.decode(int(s)), self.decode(int(p)), self.decode(int(o)))
            for s, p, o in np.asarray(spo)
        ]

    @property
    def id_capacity(self) -> int:
        """Smallest power of two >= current size (signature table extent)."""
        n = max(len(self._id_to_term), 2)
        return 1 << (n - 1).bit_length()


def parse_triple_line(line: str) -> Tuple[str, str, str] | None:
    """Parse one simplified N-Triples-ish line: ``subj pred obj .``

    Terms are whitespace-separated; a quoted literal (possibly containing
    spaces) is kept intact as the object. Returns None for blank/comment
    lines.
    """
    line = line.strip()
    if not line or line.startswith("#"):
        return None
    if line.endswith("."):
        line = line[:-1].rstrip()
    # split subject and predicate, keep the rest (possibly quoted) as object
    parts = line.split(None, 2)
    if len(parts) != 3:
        raise ValueError(f"cannot parse triple line: {line!r}")
    return parts[0], parts[1], parts[2]


def parse_triples(text: str) -> List[Tuple[str, str, str]]:
    out = []
    for line in text.splitlines():
        t = parse_triple_line(line)
        if t is not None:
            out.append(t)
    return out
