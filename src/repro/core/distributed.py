"""Distributed interest evaluation: shard_map dataflow + cohort placement.

The paper's §6 names a distributed pub/sub architecture as future work; this
module builds both halves of it on jax-native collectives:

**Within one evaluation pass** (the shard_map semijoin dataflow, DESIGN.md
§3, used by :func:`make_distributed_evaluator` and the broker's sharded
cohort step in :mod:`repro.core.broker`):

  * the target dataset is hash-partitioned TWICE: the SPO index by subject
    id, the OPS index by object id — so every bound-slot probe has exactly
    one owner shard (the classic distributed-index layout);
  * changeset rows evaluate locally on their owner shard; candidate-
    assertion probes whose binding lives on another shard are ROUTED via
    ``jax.lax.all_to_all`` (MoE-style bucketed dispatch) and answered by the
    owner.  :func:`make_routed_probe` answers one flat query vector (the
    per-interest evaluator);  :func:`make_routed_probe_batched` is the
    member-axis-aware variant for the broker's vmapped cohort steps: it
    speaks the traced-pattern (``probe_dyn``) hook contract and is written
    so that under ``jax.vmap`` over the cohort member axis every hop still
    lowers to ONE ``all_to_all`` over the flattened (member, binding)
    bucket tensor (jax's collective batching rules fold the member axis
    into the bucket payload);
  * signature tables / edge vectors / bank lane-bit words are OR-reduced
    across shards by :func:`make_or_reduce` — boolean bitsets through
    ``pmax``, uint32 lane-bit *words* through an ``all_gather`` + bitwise-OR
    fold (they are binding- or row-indexed bitsets, so the collective volume
    is independent of target size);
  * per-triple classification and output compaction stay fully local.

**Across cohorts** (the broker's placement layer): :class:`CohortPlacement`
maps whole cohorts — the independently compiled, independently schedulable
units PR 2/3 produced — onto mesh devices (round-robin, load-balanced by
padded member count, or pinned).  ``Broker(mesh=...)`` groups its
frontier-stacked cohort calls by assigned device so the per-cohort
executables run concurrently across the mesh, and
``Broker(mesh=..., shard_cohorts=True)`` instead runs every cohort pass
*inside* shard_map over the whole mesh with the hooks above.

Host-side partitioning (:func:`partition_rows`, :func:`prepare_target_shards`)
reports per-shard overflow through flags — matching the device-side
``SideResult.overflow`` discipline — instead of raising mid-pipeline; the
flags are surfaced by :func:`gather_result_sets`.

The evaluator body is *shared* with the single-device path
(``make_side_evaluator`` distribution hooks), so the semantics are identical
by construction and asserted by the equivalence tests.
"""
from __future__ import annotations

import dataclasses
import itertools
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .evaluation import TripleIndex, SideResult, make_side_evaluator, probe, probe_dyn
from .interest import CompiledInterest
from .triples import PAD, TripleStore, from_array, lex_sort


def make_mesh_compat(shape: Tuple[int, ...], axis_names: Tuple[str, ...]):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    Pre-``AxisType`` jax (< 0.5) takes no ``axis_types`` argument; newer jax
    wants the axes marked Auto so the collectives here stay legal. One home
    for the version shim, shared by the examples and the subprocess tests.
    """
    try:
        from jax.sharding import AxisType

        return jax.make_mesh(
            shape, axis_names, axis_types=(AxisType.Auto,) * len(axis_names)
        )
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axis_names)


def shard_map_compat(fn, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions, replication checking off.

    Binary-search carries and the masked-ownership dataflow mix varying and
    unvarying axes, so replication checking is disabled (``check_vma`` on
    current jax; ``check_rep`` pre-0.5).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


# ---------------------------------------------------------------------------
# host-side partitioning
# ---------------------------------------------------------------------------

def partition_rows(
    rows: np.ndarray, n_shards: int, key_col: int, cap: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(N, 3) -> (n_shards, cap, 3) hash-partitioned by ``rows[:, key_col]``.

    Returns ``(shards, overflow)`` where ``overflow`` is ``bool[n_shards]``:
    True where a shard received more than ``cap`` rows (the excess rows are
    dropped).  Overflow is a *flag*, not an exception, matching the
    device-side ``SideResult.overflow`` discipline so a pipeline can grow
    capacities between steps instead of dying mid-flight.
    """
    out = np.full((n_shards, cap, 3), PAD, np.int32)
    overflow = np.zeros((n_shards,), bool)
    if rows.size:
        dest = rows[:, key_col] % n_shards
        for s in range(n_shards):
            mine = rows[dest == s]
            if mine.shape[0] > cap:
                overflow[s] = True
                mine = mine[:cap]
            out[s, : mine.shape[0]] = mine
    return out, overflow


def prepare_target_shards(
    tau: np.ndarray, n_shards: int, cap: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(SPO shards by subject, OPS shards by object, overflow) — lex-sorted.

    OPS shards store rows permuted to (o, p, s) so the shared prefix-range
    probe machinery works unchanged.  ``overflow`` is ``bool[n_shards]``,
    the OR of the two partition passes' per-shard flags.
    """
    spo, ovf_s = partition_rows(tau, n_shards, key_col=0, cap=cap)
    ops_rows = tau[:, [2, 1, 0]] if tau.size else tau
    ops, ovf_o = partition_rows(ops_rows, n_shards, key_col=0, cap=cap)
    for s in range(n_shards):
        spo[s] = spo[s][np.lexsort((spo[s][:, 2], spo[s][:, 1], spo[s][:, 0]))]
        ops[s] = ops[s][np.lexsort((ops[s][:, 2], ops[s][:, 1], ops[s][:, 0]))]
    return spo, ops, ovf_s | ovf_o


# ---------------------------------------------------------------------------
# cohort -> device placement policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CohortPlacement:
    """cohort id -> mesh device assignment for the broker's placed mode.

    Cohorts are the broker's unit of compilation and scheduling; placement
    makes them its unit of *distribution*: each cohort's executable (inputs
    included) is committed to one mesh device, and the broker dispatches the
    frontier pass grouped by device so same-fire cohorts on different
    devices run concurrently.

    ``mode``:
      ``"round_robin"``    new cohorts cycle through the mesh devices;
      ``"load_balanced"``  a new cohort lands on the device with the least
                           accumulated padded member count (padded size is
                           what the executable actually evaluates, dummy
                           lanes included, so it is the honest load proxy);
      ``"pinned"``         explicit ``pins`` lookup (cohort signature ->
                           device index, modulo the mesh size) with
                           ``default`` as the fallback.

    Assignments are sticky: a cohort signature keeps its device across
    fires, so its τ/ρ state stays resident and steady-state fires move no
    replica data.  Load accounting is additive — a cohort whose padded size
    grows updates its device's load, but departed cohorts are not refunded
    (signatures are stable, churn within a cohort does not change its
    signature, and the estimate only seeds *new* assignments).
    """

    mode: str = "round_robin"
    pins: Dict[object, int] = dataclasses.field(default_factory=dict)
    default: int = 0

    def __post_init__(self):
        if self.mode not in ("round_robin", "load_balanced", "pinned"):
            raise ValueError(f"unknown placement mode {self.mode!r}")
        self._assigned: Dict[object, int] = {}
        self._sizes: Dict[object, int] = {}
        self._load: Dict[int, int] = {}
        self._rr = itertools.count()

    def assign(self, sig: object, padded_members: int, n_devices: int) -> int:
        """Device index for one cohort signature (sticky across calls).

        Always in ``range(n_devices)`` — a sticky assignment made against a
        larger mesh (the instance is mutable state and may be handed to a
        second broker) folds back into the current mesh instead of indexing
        past it.
        """
        dev = self._assigned.get(sig)
        if dev is not None:
            dev %= n_devices
        if dev is None:
            if self.mode == "pinned":
                dev = self.pins.get(sig, self.default) % n_devices
            elif self.mode == "load_balanced":
                dev = min(
                    range(n_devices), key=lambda i: self._load.get(i, 0)
                )
            else:
                dev = next(self._rr) % n_devices
            self._assigned[sig] = dev
            self._sizes[sig] = 0
        grown = padded_members - self._sizes[sig]
        if grown > 0:
            self._sizes[sig] = padded_members
            self._load[dev] = self._load.get(dev, 0) + grown
        return dev


# ---------------------------------------------------------------------------
# in-graph primitives (inside shard_map)
# ---------------------------------------------------------------------------

def _bucketize(vals: jax.Array, n: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Group vals (B,) by dest = val % n into (n, B) buckets (PAD-padded).

    Returns (buckets, dest, pos) so responses can be scattered back.
    """
    b = vals.shape[0]
    live = vals != PAD
    dest = jnp.where(live, vals % n, n)  # PAD -> dropped
    onehot = jax.nn.one_hot(dest, n, dtype=jnp.int32)  # (B, n)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_of = jnp.sum(pos * onehot, axis=1)  # (B,)
    buckets = jnp.full((n, b), PAD, jnp.int32)
    buckets = buckets.at[dest, pos_of].set(vals, mode="drop")
    return buckets, dest, pos_of


def make_routed_probe(axis: str, n_shards: int) -> Callable:
    """all_to_all probe: queries travel to the owner shard, answers return.

    Static-pattern hook contract (``make_side_evaluator(probe_impl=...)``
    without ``dynamic_patterns``):
    ``(index, pattern, bound_slot, bound_vals, fanout)``.
    """

    def routed(index: TripleIndex, pattern, bound_slot, bound_vals, fanout):
        return _routed_exchange(
            axis,
            n_shards,
            bound_vals,
            lambda recv: probe(index, pattern, bound_slot, recv, fanout),
            fanout,
        )

    return routed


def make_routed_probe_batched(axis: str, n_shards: int) -> Callable:
    """Member-axis-aware routed probe with traced pattern values.

    Speaks the *dynamic* hook contract of ``make_side_evaluator(
    dynamic_patterns=True, probe_impl=...)``:
    ``(index, pattern_host, pattern_dev, bound_slot, bound_vals, fanout)``
    — ``pattern_host`` carries the static const/var structure, ``pattern_dev``
    the traced comparison values (they differ per cohort member).

    The broker's cohort steps call this under ``jax.vmap`` over the member
    axis.  Every operation here is pointwise in the member dimension and the
    collectives carry jax's batching rules, so one *logical* probe hop per
    member lowers to ONE physical ``all_to_all`` over the flattened
    (member, binding) bucket tensor — the member axis rides inside the
    bucket payload, exactly like bucketized MoE dispatch.  The owner shard
    answers from its local hash partition: partition key == bound slot
    (subject for SPO probes, object for OPS probes), so the owner holds the
    *complete* prefix range for every query it receives and the answers —
    including the ``fanout`` truncation order — are bit-identical to a probe
    of the unpartitioned index.
    """

    def routed(
        index: TripleIndex,
        pattern_host,
        pattern_dev,
        bound_slot,
        bound_vals,
        fanout,
    ):
        return _routed_exchange(
            axis,
            n_shards,
            bound_vals,
            lambda recv: probe_dyn(
                index, pattern_host, pattern_dev, bound_slot, recv, fanout
            ),
            fanout,
        )

    return routed


def _routed_exchange(
    axis: str,
    n_shards: int,
    bound_vals: jax.Array,
    local_probe: Callable[[jax.Array], Tuple[jax.Array, jax.Array]],
    fanout: int,
) -> Tuple[jax.Array, jax.Array]:
    """Shared send/answer/return machinery of the routed probes."""
    b = bound_vals.shape[0]
    buckets, dest, pos = _bucketize(bound_vals, n_shards)
    # send: each shard receives one (B,) bucket from every peer
    recv = jax.lax.all_to_all(buckets, axis, 0, 0)  # (n, B) queries for me
    rows, valid = local_probe(recv.reshape(-1))
    rows = rows.reshape(n_shards, b, fanout, 3)
    valid = valid.reshape(n_shards, b, fanout)
    # return: answers go back to the asking shard
    rows_back = jax.lax.all_to_all(rows, axis, 0, 0)  # (n, B, K, 3)
    valid_back = jax.lax.all_to_all(
        valid.astype(jnp.int8), axis, 0, 0
    ).astype(bool)
    # un-bucketize: my query i was sent to shard dest[i] at slot pos[i]
    my_rows = rows_back[dest.clip(0, n_shards - 1), pos]
    my_valid = valid_back[dest.clip(0, n_shards - 1), pos] & (
        bound_vals != PAD
    )[:, None]
    return my_rows, my_valid


def make_or_reduce(axis: str) -> Callable:
    """Cross-shard OR: boolean bitsets via ``pmax``, lane-bit words via
    ``all_gather`` + bitwise-OR fold.

    The evaluator's signature tables / edge vectors are boolean and reduce
    through ``pmax``.  The uint32 path generalizes the hook to *lane-bit
    words*: shards that each computed a masked subset of a words tensor
    (zeros elsewhere) reassemble the full tensor by OR — exact and
    order-independent even when the subsets overlap.  (For the broker's
    disjoint block splits, gathering just the blocks and stitching them at
    static offsets is cheaper — ``make_sharded_cohort_step`` does that —
    but masked/overlapping decompositions, e.g. under custom matcher hooks,
    need the OR fold.)  Both forms batch correctly under ``jax.vmap``.
    """

    def or_reduce(t: jax.Array) -> jax.Array:
        if t.dtype == jnp.bool_:
            return jax.lax.pmax(t.astype(jnp.uint8), axis).astype(bool)
        gathered = jax.lax.all_gather(t, axis)  # (n_shards, ...)
        acc = gathered[0]
        for i in range(1, gathered.shape[0]):
            acc = acc | gathered[i]
        return acc

    return or_reduce


def route_rows_by_key(rows: jax.Array, axis: str, n_shards: int, key_col: int = 0):
    """Send each row to the shard owning ``row[key_col]`` (for Υ set algebra).

    rows: (N, 3) local, PAD-padded. Returns (n * N, 3) rows now resident on
    the owner shard (PAD-padded, unsorted).
    """
    n_rows = rows.shape[0]
    key = rows[:, key_col]
    buckets, dest, pos = _bucketize(key, n_shards)
    full_buckets = jnp.full((n_shards, n_rows, 3), PAD, jnp.int32)
    full_buckets = full_buckets.at[dest, pos].set(rows, mode="drop")
    recv = jax.lax.all_to_all(full_buckets, axis, 0, 0)
    return recv.reshape(-1, 3)


# ---------------------------------------------------------------------------
# the distributed side evaluator
# ---------------------------------------------------------------------------

def make_distributed_evaluator(
    plan: CompiledInterest,
    mesh,
    *,
    axis: str = "data",
    id_capacity: int,
    fanout: int = 4,
    out_capacity: int,
    pull_capacity: int,
):
    """shard_map side evaluator over hash-partitioned (M, τ) shards.

    Inputs (global views):
      m_shards:   int32[n, m_cap, 3]      changeset rows (any partitioning)
      spo_shards: int32[n, t_cap, 3]      τ partitioned by subject, sorted
      ops_shards: int32[n, t_cap, 3]      τ (o,p,s) partitioned by object
    Returns per-shard SideResult stacked on the leading axis.
    """
    n_shards = int(mesh.shape[axis])
    evaluator = make_side_evaluator(
        plan,
        id_capacity=id_capacity,
        fanout=fanout,
        out_capacity=out_capacity,
        pull_capacity=pull_capacity,
        probe_impl=make_routed_probe(axis, n_shards),
        table_reduce=make_or_reduce(axis),
    )

    def shard_fn(m_rows, spo_rows, ops_rows):
        m_store = TripleStore(
            spo=lex_sort(m_rows[0]),
            n=jnp.sum(m_rows[0, :, 0] != PAD, dtype=jnp.int32),
        )
        tgt = TripleIndex(
            spo=TripleStore(
                spo=spo_rows[0],
                n=jnp.sum(spo_rows[0, :, 0] != PAD, dtype=jnp.int32),
            ),
            ops=TripleStore(
                spo=ops_rows[0],
                n=jnp.sum(ops_rows[0, :, 0] != PAD, dtype=jnp.int32),
            ),
        )
        res = evaluator(m_store, tgt)
        return jax.tree.map(lambda t: t[None], res)

    spec = P(axis, None, None)
    out_specs = SideResult(
        interesting=TripleStore(spo=P(axis, None, None), n=P(axis)),
        potential=TripleStore(spo=P(axis, None, None), n=P(axis)),
        pulls=TripleStore(spo=P(axis, None, None), n=P(axis)),
        overflow=P(axis),
    )
    mapped = shard_map_compat(
        shard_fn, mesh, in_specs=(spec, spec, spec), out_specs=out_specs
    )
    return jax.jit(mapped)


def gather_result_sets(res: SideResult, partition_overflow=None):
    """Union the per-shard outputs into host-side sets (for tests/stats).

    Returns ``(interesting, potential, pulls, overflow)``; ``overflow`` ORs
    the per-shard device flags with any host-side partition flags passed in
    (one or more ``bool[n_shards]`` arrays from :func:`partition_rows` /
    :func:`prepare_target_shards`), so a pipeline sees every capacity
    violation — host or device — through one value.
    """
    def rows_of(store_stacked):
        arr = np.asarray(store_stacked.spo).reshape(-1, 3)
        return {tuple(int(x) for x in r) for r in arr if r[0] != PAD}

    overflow = bool(np.any(np.asarray(res.overflow)))
    if partition_overflow is not None:
        overflow = overflow or bool(np.any(np.asarray(partition_overflow)))
    return (
        rows_of(res.interesting),
        rows_of(res.potential),
        rows_of(res.pulls),
        overflow,
    )
