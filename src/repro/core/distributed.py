"""Distributed interest evaluation: shard_map semijoin dataflow (DESIGN.md §3).

The paper's §6 names a distributed pub/sub architecture as future work; this
module builds it on jax-native collectives:

  * the target dataset is hash-partitioned TWICE: the SPO index by subject id,
    the OPS index by object id — so every bound-slot probe has exactly one
    owner shard (the classic distributed-index layout);
  * changeset shards evaluate locally; candidate-assertion probes whose
    binding lives on another shard are ROUTED via ``jax.lax.all_to_all``
    (MoE-style bucketed dispatch) and answered by the owner;
  * signature tables / edge vectors are OR-all-reduced (they are binding-
    indexed bitsets, so the collective volume is O(R x n_patterns) —
    independent of changeset size);
  * per-triple classification and output compaction stay fully local.

The evaluator body is *shared* with the single-device path
(``make_side_evaluator`` distribution hooks), so the semantics are identical
by construction and asserted by the equivalence tests.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .evaluation import TripleIndex, SideResult, make_side_evaluator, probe
from .interest import CompiledInterest
from .triples import PAD, TripleStore, from_array, lex_sort


# ---------------------------------------------------------------------------
# host-side partitioning
# ---------------------------------------------------------------------------

def partition_rows(rows: np.ndarray, n_shards: int, key_col: int, cap: int) -> np.ndarray:
    """(N, 3) -> (n_shards, cap, 3) hash-partitioned by ``rows[:, key_col]``."""
    out = np.full((n_shards, cap, 3), PAD, np.int32)
    if rows.size:
        dest = rows[:, key_col] % n_shards
        for s in range(n_shards):
            mine = rows[dest == s]
            if mine.shape[0] > cap:
                raise ValueError(f"shard {s} overflows cap {cap}")
            out[s, : mine.shape[0]] = mine
    return out


def prepare_target_shards(
    tau: np.ndarray, n_shards: int, cap: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(SPO shards by subject, OPS shards by object) — both lex-sorted rows.

    OPS shards store rows permuted to (o, p, s) so the shared prefix-range
    probe machinery works unchanged.
    """
    spo = partition_rows(tau, n_shards, key_col=0, cap=cap)
    ops_rows = tau[:, [2, 1, 0]] if tau.size else tau
    ops = partition_rows(ops_rows, n_shards, key_col=0, cap=cap)
    for s in range(n_shards):
        spo[s] = spo[s][np.lexsort((spo[s][:, 2], spo[s][:, 1], spo[s][:, 0]))]
        ops[s] = ops[s][np.lexsort((ops[s][:, 2], ops[s][:, 1], ops[s][:, 0]))]
    return spo, ops


# ---------------------------------------------------------------------------
# in-graph primitives (inside shard_map)
# ---------------------------------------------------------------------------

def _bucketize(vals: jax.Array, n: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Group vals (B,) by dest = val % n into (n, B) buckets (PAD-padded).

    Returns (buckets, dest, pos) so responses can be scattered back.
    """
    b = vals.shape[0]
    live = vals != PAD
    dest = jnp.where(live, vals % n, n)  # PAD -> dropped
    onehot = jax.nn.one_hot(dest, n, dtype=jnp.int32)  # (B, n)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_of = jnp.sum(pos * onehot, axis=1)  # (B,)
    buckets = jnp.full((n, b), PAD, jnp.int32)
    buckets = buckets.at[dest, pos_of].set(vals, mode="drop")
    return buckets, dest, pos_of


def make_routed_probe(axis: str, n_shards: int) -> Callable:
    """all_to_all probe: queries travel to the owner shard, answers return."""

    def routed(index: TripleIndex, pattern, bound_slot, bound_vals, fanout):
        b = bound_vals.shape[0]
        buckets, dest, pos = _bucketize(bound_vals, n_shards)
        # send: each shard receives one (B,) bucket from every peer
        recv = jax.lax.all_to_all(buckets, axis, 0, 0)  # (n, B) queries for me
        rows, valid = probe(
            index, pattern, bound_slot, recv.reshape(-1), fanout
        )
        rows = rows.reshape(n_shards, b, fanout, 3)
        valid = valid.reshape(n_shards, b, fanout)
        # return: answers go back to the asking shard
        rows_back = jax.lax.all_to_all(rows, axis, 0, 0)  # (n, B, K, 3)
        valid_back = jax.lax.all_to_all(
            valid.astype(jnp.int8), axis, 0, 0
        ).astype(bool)
        # un-bucketize: my query i was sent to shard dest[i] at slot pos[i]
        my_rows = rows_back[dest.clip(0, n_shards - 1), pos]
        my_valid = valid_back[dest.clip(0, n_shards - 1), pos] & (
            bound_vals != PAD
        )[:, None]
        return my_rows, my_valid

    return routed


def make_or_reduce(axis: str) -> Callable:
    def or_reduce(t: jax.Array) -> jax.Array:
        return jax.lax.pmax(t.astype(jnp.uint8), axis).astype(bool)

    return or_reduce


def route_rows_by_key(rows: jax.Array, axis: str, n_shards: int, key_col: int = 0):
    """Send each row to the shard owning ``row[key_col]`` (for Υ set algebra).

    rows: (N, 3) local, PAD-padded. Returns (n * N, 3) rows now resident on
    the owner shard (PAD-padded, unsorted).
    """
    n_rows = rows.shape[0]
    key = rows[:, key_col]
    buckets, dest, pos = _bucketize(key, n_shards)
    full_buckets = jnp.full((n_shards, n_rows, 3), PAD, jnp.int32)
    full_buckets = full_buckets.at[dest, pos].set(rows, mode="drop")
    recv = jax.lax.all_to_all(full_buckets, axis, 0, 0)
    return recv.reshape(-1, 3)


# ---------------------------------------------------------------------------
# the distributed side evaluator
# ---------------------------------------------------------------------------

def make_distributed_evaluator(
    plan: CompiledInterest,
    mesh,
    *,
    axis: str = "data",
    id_capacity: int,
    fanout: int = 4,
    out_capacity: int,
    pull_capacity: int,
):
    """shard_map side evaluator over hash-partitioned (M, τ) shards.

    Inputs (global views):
      m_shards:   int32[n, m_cap, 3]      changeset rows (any partitioning)
      spo_shards: int32[n, t_cap, 3]      τ partitioned by subject, sorted
      ops_shards: int32[n, t_cap, 3]      τ (o,p,s) partitioned by object
    Returns per-shard SideResult stacked on the leading axis.
    """
    n_shards = int(mesh.shape[axis])
    evaluator = make_side_evaluator(
        plan,
        id_capacity=id_capacity,
        fanout=fanout,
        out_capacity=out_capacity,
        pull_capacity=pull_capacity,
        probe_impl=make_routed_probe(axis, n_shards),
        table_reduce=make_or_reduce(axis),
    )

    def shard_fn(m_rows, spo_rows, ops_rows):
        m_store = TripleStore(
            spo=lex_sort(m_rows[0]),
            n=jnp.sum(m_rows[0, :, 0] != PAD, dtype=jnp.int32),
        )
        tgt = TripleIndex(
            spo=TripleStore(
                spo=spo_rows[0],
                n=jnp.sum(spo_rows[0, :, 0] != PAD, dtype=jnp.int32),
            ),
            ops=TripleStore(
                spo=ops_rows[0],
                n=jnp.sum(ops_rows[0, :, 0] != PAD, dtype=jnp.int32),
            ),
        )
        res = evaluator(m_store, tgt)
        return jax.tree.map(lambda t: t[None], res)

    spec = P(axis, None, None)
    out_specs = SideResult(
        interesting=TripleStore(spo=P(axis, None, None), n=P(axis)),
        potential=TripleStore(spo=P(axis, None, None), n=P(axis)),
        pulls=TripleStore(spo=P(axis, None, None), n=P(axis)),
        overflow=P(axis),
    )
    # binary-search carries mix varying/unvarying axes, so replication
    # checking is off (check_vma on current jax; check_rep pre-0.5)
    if hasattr(jax, "shard_map"):
        mapped = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(spec, spec, spec),
            out_specs=out_specs,
            check_vma=False,
        )
    else:
        from jax.experimental.shard_map import shard_map as _shard_map

        mapped = _shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(spec, spec, spec),
            out_specs=out_specs,
            check_rep=False,
        )
    return jax.jit(mapped)


def gather_result_sets(res: SideResult):
    """Union the per-shard outputs into host-side sets (for tests/stats)."""
    def rows_of(store_stacked):
        arr = np.asarray(store_stacked.spo).reshape(-1, 3)
        return {tuple(int(x) for x in r) for r in arr if r[0] != PAD}

    return (
        rows_of(res.interesting),
        rows_of(res.potential),
        rows_of(res.pulls),
    )
