"""Pure-Python reference implementation of the iRap semantics (sets + loops).

The oracle mirrors DESIGN.md §1 exactly — the same root/child/edge tree
semantics, the same interesting / potential / pull rules — but with unbounded
sets and exhaustive enumeration. Property tests drive random changesets
through both the oracle and the jitted evaluator and require identical sets
(fan-out-capped data).
"""
from __future__ import annotations

import itertools
from typing import Dict, FrozenSet, List, Sequence, Set, Tuple

from .interest import CompiledInterest

Triple = Tuple[int, int, int]


def _matches(pattern, eq, triple: Triple) -> bool:
    for k in range(3):
        c = int(pattern[k])
        if c >= 0 and triple[k] != c:
            return False
    if eq is not None and triple[eq[0]] != triple[eq[1]]:
        return False
    return True


class OracleEvaluator:
    """Reference one-side evaluation + full changeset step."""

    def __init__(self, plan: CompiledInterest):
        self.plan = plan
        p = plan
        self.root_js = [j for j in range(p.n_total) if p.kinds[j] == "root"]
        self.edge_js = [j for j in range(p.n_total) if p.kinds[j] == "edge"]
        self.child_js = [j for j in range(p.n_total) if p.kinds[j] == "child"]
        self.bgp_root = [j for j in self.root_js if j < p.n_bgp]
        self.bgp_edge = [j for j in self.edge_js if j < p.n_bgp]
        self.child_bgp_stars = {
            cv: [j for j in self.child_js if p.child_var[j] == cv and j < p.n_bgp]
            for cv in range(p.n_children)
        }
        self.child_all_stars = {
            cv: [j for j in self.child_js if p.child_var[j] == cv]
            for cv in range(p.n_children)
        }
        self.edges_of = {
            cv: [e for e in self.edge_js if p.child_var[e] == cv]
            for cv in range(p.n_children)
        }

    # -- helpers ----------------------------------------------------------
    def _match_j(self, j: int, t: Triple) -> bool:
        return _matches(self.plan.patterns[j], self.plan.eq_pairs[j], t)

    def _probe(self, tgt: Set[Triple], j: int, slot: int, val: int) -> List[Triple]:
        return sorted(
            t for t in tgt if self._match_j(j, t) and t[slot] == val
        )

    # -- one-side evaluation ------------------------------------------------
    def evaluate_side(self, m: Set[Triple], tgt: Set[Triple]):
        p = self.plan
        anchor, cslot, cvar = p.anchor_slot, p.child_slot, p.child_var

        def m_bits(t: Triple) -> List[int]:
            return [j for j in range(p.n_total) if self._match_j(j, t)]

        # generation signature
        sat_gen: Dict[Tuple[int, int], bool] = {}
        for t in m:
            for j in self.root_js + self.child_js:
                if self._match_j(j, t):
                    sat_gen[(t[anchor[j]], j)] = True

        # candidate pools
        root_cand: Set[int] = set()
        for t in m:
            for j in self.root_js:
                if self._match_j(j, t):
                    root_cand.add(t[anchor[j]])
            for e in self.edge_js:
                if self._match_j(e, t):
                    root_cand.add(t[anchor[e]])

        # edge pools: edge id -> list of (b, c, triple, is_pull)
        edge_pool: Dict[int, List[Tuple[int, int, Triple, bool]]] = {
            e: [] for e in self.edge_js
        }
        for e in self.edge_js:
            for t in m:
                if self._match_j(e, t):
                    edge_pool[e].append((t[anchor[e]], t[cslot[e]], t, False))
            # upward probes from child-star M bindings
            for j in self.child_all_stars[cvar[e]]:
                for t in m:
                    if self._match_j(j, t):
                        c = t[anchor[j]]
                        for row in self._probe(tgt, e, cslot[e], c):
                            edge_pool[e].append(
                                (row[anchor[e]], row[cslot[e]], row, True)
                            )
                            root_cand.add(row[anchor[e]])
        # downward probes
        for e in self.edge_js:
            for b in sorted(root_cand):
                for row in self._probe(tgt, e, anchor[e], b):
                    edge_pool[e].append((row[anchor[e]], row[cslot[e]], row, True))

        child_cand: Dict[int, Set[int]] = {cv: set() for cv in range(p.n_children)}
        for cv in range(p.n_children):
            for j in self.child_all_stars[cv]:
                for t in m:
                    if self._match_j(j, t):
                        child_cand[cv].add(t[anchor[j]])
            for e in self.edges_of[cv]:
                for b, c, row, is_pull in edge_pool[e]:
                    child_cand[cv].add(c)

        # assertion probes
        sat_tgt: Dict[Tuple[int, int], bool] = {}
        pull_entries = []  # (kind, j, cv, binding, rows)
        for j in self.child_js:
            cv = cvar[j]
            for c in sorted(child_cand[cv]):
                rows = self._probe(tgt, j, anchor[j], c)
                if rows:
                    sat_tgt[(c, j)] = True
                pull_entries.append(("child", j, cv, c, rows))
        for j in self.root_js:
            for b in sorted(root_cand):
                rows = self._probe(tgt, j, anchor[j], b)
                if rows:
                    sat_tgt[(b, j)] = True
                pull_entries.append(("root", j, -1, b, rows))

        def sat(b: int, j: int) -> bool:
            return sat_gen.get((b, j), False) or sat_tgt.get((b, j), False)

        def child_ok(cv: int, c: int) -> bool:
            return all(sat(c, j) for j in self.child_bgp_stars[cv])

        def edge_ok(e: int, b: int) -> bool:
            return any(
                bb == b and child_ok(cvar[e], c)
                for bb, c, row, is_pull in edge_pool[e]
            )

        def full(b: int) -> bool:
            if not self.bgp_root and not self.bgp_edge:
                return False
            return all(sat(b, j) for j in self.bgp_root) and all(
                edge_ok(e, b) for e in self.bgp_edge
            )

        def linked_full(cv: int, c: int) -> bool:
            return any(
                cc == c and full(b)
                for e in self.edges_of[cv]
                for b, cc, row, is_pull in edge_pool[e]
            )

        interesting: Set[Triple] = set()
        potential: Set[Triple] = set()
        for t in m:
            bits = m_bits(t)
            inter = False
            for j in bits:
                if p.kinds[j] == "root":
                    inter |= full(t[anchor[j]])
                elif p.kinds[j] == "edge":
                    inter |= full(t[anchor[j]]) and child_ok(cvar[j], t[cslot[j]])
                else:
                    c = t[anchor[j]]
                    inter |= child_ok(cvar[j], c) and linked_full(cvar[j], c)
            if inter:
                interesting.add(t)
            elif bits:
                potential.add(t)

        pulls: Set[Triple] = set()
        for kind, j, cv, b, rows in pull_entries:
            if sat_gen.get((b, j), False):
                continue  # only missing patterns are pulled (Def 12)
            if kind == "root":
                gate = full(b)
            else:
                gate = child_ok(cv, b) and linked_full(cv, b)
            if gate:
                pulls.update(rows)
        for e in self.edge_js:
            for b, c, row, is_pull in edge_pool[e]:
                if is_pull and full(b) and child_ok(cvar[e], c):
                    pulls.add(row)

        return interesting, potential, pulls

    # -- full changeset step (Defs 13-18) -----------------------------------
    def step(
        self,
        d_set: Set[Triple],
        a_set: Set[Triple],
        tau: Set[Triple],
        rho: Set[Triple],
    ):
        r, r_i, r_prime = self.evaluate_side(set(d_set), set(tau))
        i_set = set(a_set) | set(rho)
        a_int, a_i, a_pulls = self.evaluate_side(i_set, set(tau))
        a = a_int | a_pulls
        tau1 = (tau - (r | r_prime)) | a
        rho1 = ((rho - r_i) | a_i | r_prime) - a
        return {
            "r": r,
            "r_i": r_i,
            "r_prime": r_prime,
            "a": a,
            "a_i": a_i,
            "tau1": tau1,
            "rho1": rho1,
        }
