"""Interest expressions (Definition 7) and their compilation to static plans.

An interest expression ``i_g = <τ, b, op>`` is compiled into a
``CompiledInterest``: dictionary-encoded pattern tensors plus a static query
plan (root variable, child stars, edge patterns) that the jitted evaluator in
:mod:`repro.core.evaluation` closes over.

Supported BGP shape (covers both paper evaluation queries and the running
example): connected patterns whose join graph is a tree of depth <= 2
(one root variable + any number of child variables each linked to the root by
one or more edge patterns). Join variables in predicate position and cyclic
join graphs are rejected at compile time (DESIGN.md §1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dictionary import Dictionary
from .triples import WILDCARD

SLOT_NAMES = ("subject", "predicate", "object")


def is_var(term: str) -> bool:
    return term.startswith("?")


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    s: str
    p: str
    o: str

    def slots(self) -> Tuple[str, str, str]:
        return (self.s, self.p, self.o)


@dataclasses.dataclass(frozen=True)
class InterestExpr:
    """i_g = <source g, target τ, BGP b, OGP op> (Definition 7)."""

    source: str
    target: str
    bgp: Tuple[TriplePattern, ...]
    ogp: Tuple[TriplePattern, ...] = ()

    @staticmethod
    def parse(source: str, target: str, bgp: Sequence[Tuple[str, str, str]],
              ogp: Sequence[Tuple[str, str, str]] = ()) -> "InterestExpr":
        return InterestExpr(
            source=source,
            target=target,
            bgp=tuple(TriplePattern(*t) for t in bgp),
            ogp=tuple(TriplePattern(*t) for t in ogp),
        )


def canonicalize_expr(expr: InterestExpr) -> Tuple[InterestExpr, tuple]:
    """Canonical form of an interest expression; returns ``(expr', key)``.

    **Canonical-form contract.** A BGP/OGP is a *set* of triple patterns and
    variable names are bound positions, not identities (Definitions 2-4), so
    two expressions that differ only in pattern order and/or a bijective
    variable renaming denote the same interest. This function maps every
    member of such an equivalence class that it can recognize onto one
    representative:

    1. patterns are ordered by their *constant skeleton* (each variable slot
       replaced by ``"?"``) — a key independent of variable naming;
    2. variables are renamed ``?v0, ?v1, ...`` in order of first occurrence
       over the skeleton-sorted BGP then OGP;
    3. patterns are re-sorted by their full (renamed) term tuples, making
       the order independent of the input order even among patterns with
       equal skeletons.

    Guarantees: **equal keys imply equivalent interests** — the key embeds
    the source/target names and the complete renamed pattern lists, and the
    canonical expression is reconstructed from the input by a permutation
    plus a bijective renaming only, so any two expressions with the same
    key are permutations/renamings of the same canonical expression and
    evaluate identically (bit-identically: evaluation outputs are canonical
    lex-sorted stores, which erase pattern order). The converse does NOT
    hold: expressions whose equivalence needs a non-trivial automorphism
    argument may land on different keys — that costs a missed collapse in
    the broker's subsumption lattice, never a wrong one.

    The broker compiles and evaluates the *canonical* expression for every
    subscription in a lane group, so equal keys also share compiled plans,
    bank lanes, and cohort slots.
    """

    def skeleton(p: TriplePattern) -> Tuple[str, str, str]:
        return tuple("?" if is_var(t) else t for t in p.slots())

    bgp = sorted(expr.bgp, key=skeleton)
    ogp = sorted(expr.ogp, key=skeleton)
    renames: Dict[str, str] = {}

    def rename(t: str) -> str:
        if not is_var(t):
            return t
        if t not in renames:
            renames[t] = f"?v{len(renames)}"
        return renames[t]

    bgp = [TriplePattern(*(rename(t) for t in p.slots())) for p in bgp]
    ogp = [TriplePattern(*(rename(t) for t in p.slots())) for p in ogp]
    bgp = tuple(sorted(bgp, key=lambda p: p.slots()))
    ogp = tuple(sorted(ogp, key=lambda p: p.slots()))
    canon = InterestExpr(
        source=expr.source, target=expr.target, bgp=bgp, ogp=ogp
    )
    key = (
        expr.source,
        expr.target,
        tuple(p.slots() for p in bgp),
        tuple(p.slots() for p in ogp),
    )
    return canon, key


@dataclasses.dataclass(frozen=True)
class CompiledInterest:
    """Static evaluation plan for one interest expression.

    Pattern order: BGP patterns first, then OGP patterns. Per-pattern kind:
    ``root``  — anchored at the root variable (star pattern, incl. const-root)
    ``edge``  — links root variable to a child variable
    ``child`` — anchored at a child variable (subtree star)
    """

    patterns: np.ndarray  # (n_total, 3) int32; -1 where the slot is a variable
    n_bgp: int
    n_ogp: int
    kinds: Tuple[str, ...]
    anchor_slot: Tuple[int, ...]  # grouping slot (root-side slot for edges)
    child_slot: Tuple[int, ...]  # edge: slot of the child var; else -1
    child_var: Tuple[int, ...]  # edge/child patterns: child var index; else -1
    eq_pairs: Tuple[Optional[Tuple[int, int]], ...]  # repeated-var-in-pattern
    root_var: str
    child_vars: Tuple[str, ...]
    source: str
    target: str

    @property
    def n_total(self) -> int:
        return self.n_bgp + self.n_ogp

    @property
    def n_children(self) -> int:
        return len(self.child_vars)

    def bgp_ids(self) -> range:
        return range(self.n_bgp)

    def child_bgp_patterns(self, cvar: int) -> List[int]:
        return [
            j for j in range(self.n_bgp)
            if self.kinds[j] == "child" and self.child_var[j] == cvar
        ]

    def child_edges(self, cvar: int) -> List[int]:
        return [
            j for j in range(self.n_bgp)
            if self.kinds[j] == "edge" and self.child_var[j] == cvar
        ]


@dataclasses.dataclass(frozen=True)
class PatternBank:
    """Consolidated triple-pattern bank shared by many compiled interests.

    Distinct (s, p, o) pattern rows across all registered interests are
    deduplicated into one bank; each plan keeps a static lane map from its
    local pattern index to the bank lane carrying that pattern's match bit.
    A pattern shared by K interests is evaluated once per changeset pass and
    its bit fanned out K ways (kernels.ops.lane_bits). Per-pattern
    constraints that are *not* functions of the raw (s, p, o) row alone —
    the repeated-variable ``eq_pairs`` masks — stay per-plan downstream, so
    dedup by row is exact.
    """

    patterns: np.ndarray  # (n_lanes, 3) int32; -1 where the slot is a variable
    lanes: Tuple[Tuple[int, ...], ...]  # per plan: local pattern j -> bank lane

    @property
    def n_lanes(self) -> int:
        return int(self.patterns.shape[0])

    @property
    def n_words(self) -> int:
        """uint32 bitset words needed to carry every lane (chunking unit)."""
        return max(1, -(-self.n_lanes // 32))


def build_pattern_bank(plans: Sequence[CompiledInterest]) -> PatternBank:
    """Dedup the patterns of many plans into one bank with lane maps."""
    table: Dict[Tuple[int, int, int], int] = {}
    rows: List[Tuple[int, int, int]] = []
    lanes: List[Tuple[int, ...]] = []
    for plan in plans:
        local: List[int] = []
        for j in range(plan.n_total):
            key = (
                int(plan.patterns[j, 0]),
                int(plan.patterns[j, 1]),
                int(plan.patterns[j, 2]),
            )
            if key not in table:
                table[key] = len(rows)
                rows.append(key)
            local.append(table[key])
        lanes.append(tuple(local))
    pat = np.asarray(rows, dtype=np.int32).reshape(len(rows), 3)
    return PatternBank(patterns=pat, lanes=tuple(lanes))


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1). The padding rule shared by
    cohort sizes, bank lane counts, and batch capacities: power-of-two
    shapes are what lets churn reuse cached executables."""
    return 1 << max(0, n - 1).bit_length()


# A bank row that matches nothing: every slot is the PAD sentinel, which no
# dictionary-encoded triple can carry (ids are dense and < 2**31 - 1) and
# which the matchers additionally exclude via the valid-row mask. Used for
# tombstoned lanes and for padding the bank to a stable device shape.
_DEAD_ROW = (int(np.iinfo(np.int32).max),) * 3


class IncrementalPatternBank:
    """Mutable pattern bank with *stable* lane numbering under churn.

    :func:`build_pattern_bank` assigns lanes by rebuilding the whole table,
    so any subscription change renumbers every plan's lane map and — because
    lane maps and the bank array feed the broker's compiled cohort steps —
    invalidates executables that had nothing to do with the change. This
    class makes the bank an incremental structure instead:

    * ``add_plan`` dedups against the live table and extends the bank only
      with genuinely new rows; existing lanes are never renumbered.
    * ``remove_plan`` decrements per-lane refcounts; lanes that drop to zero
      are *tombstoned* (their row becomes the never-matching ``_DEAD_ROW``)
      rather than removed, so every other plan's lane map stays valid.
      Tombstoned lanes are reused first by later ``add_plan`` calls, which
      keeps re-subscription churn from growing the bank at all.
    * ``maybe_compact`` renumbers only when doing so would actually shrink
      the padded device bank shape (the padded-word boundary) — the caller
      applies the returned remap to all live lane maps. Tombstone *count*
      is irrelevant on its own: the bank array is padded to a power of two
      and executables key on that padded shape, so a compaction that lands
      in the same padded bucket would churn every live lane map (and every
      cached static-array signature) for zero executable-shape benefit.

    ``patterns_padded`` pads the lane count to a power of two (min 32, i.e.
    whole uint32 bitset words) so the bank's *device shape* — part of every
    cohort executable's input signature — changes only when the bank crosses
    a power-of-two boundary, not on every subscription.

    ``version`` increments whenever the padded array contents change; the
    broker uses it to refresh its device copy cheaply.
    """

    def __init__(self):
        self._table: Dict[Tuple[int, int, int], int] = {}
        self._rows: List[Optional[Tuple[int, int, int]]] = []
        self._refs: List[int] = []
        self._free: List[int] = []  # tombstoned lanes, reused LIFO
        self.version = 0

    @property
    def n_lanes(self) -> int:
        """Allocated lanes, including tombstones (the padded-shape driver)."""
        return len(self._rows)

    @property
    def n_live(self) -> int:
        return len(self._rows) - len(self._free)

    @property
    def n_words(self) -> int:
        return max(1, -(-len(self._rows) // 32))

    @property
    def n_lanes_padded(self) -> int:
        """Power-of-two (>= 32) lane count of :meth:`patterns_padded`."""
        return next_pow2(max(32, len(self._rows)))

    def acquire_row(self, key: Tuple[int, int, int]) -> int:
        """Refcount-acquire one pattern row, allocating a lane if new."""
        lane = self._table.get(key)
        if lane is None:
            if self._free:
                lane = self._free.pop()
                self._rows[lane] = key
                self._refs[lane] = 0
            else:
                lane = len(self._rows)
                self._rows.append(key)
                self._refs.append(0)
            self._table[key] = lane
            self.version += 1
        self._refs[lane] += 1
        return lane

    def retain_lane(self, lane: int) -> None:
        """Extra reference on an already-live lane (no key lookup)."""
        if self._rows[lane] is None:
            raise ValueError(f"lane {lane} is tombstoned")
        self._refs[lane] += 1

    def release_row(self, lane: int) -> None:
        """Drop one reference; tombstone the lane when it hits zero."""
        self._refs[lane] -= 1
        if self._refs[lane] == 0:
            del self._table[self._rows[lane]]
            self._rows[lane] = None
            self._free.append(lane)
            self.version += 1
        elif self._refs[lane] < 0:
            raise ValueError(f"lane {lane} released more than acquired")

    def lane_of(self, key: Tuple[int, int, int]) -> Optional[int]:
        return self._table.get(key)

    def row_of(self, lane: int) -> Optional[Tuple[int, int, int]]:
        return self._rows[lane]

    def live_lanes(self) -> List[int]:
        return sorted(self._table.values())

    def add_plan(self, plan: CompiledInterest) -> Tuple[int, ...]:
        """Register one plan's patterns; returns its (stable) lane map."""
        return tuple(
            self.acquire_row(
                (
                    int(plan.patterns[j, 0]),
                    int(plan.patterns[j, 1]),
                    int(plan.patterns[j, 2]),
                )
            )
            for j in range(plan.n_total)
        )

    def remove_plan(self, lanes: Sequence[int]) -> None:
        """Release one plan's lanes (symmetric with :meth:`add_plan`)."""
        for lane in lanes:
            self.release_row(lane)

    def maybe_compact(self, force: bool = False) -> Optional[Dict[int, int]]:
        """Renumber away tombstones when that shrinks the padded bank shape.

        Compaction is driven by the padded-word boundary, not the raw
        tombstone fraction: it runs exactly when the live lanes would pad
        to a strictly smaller power-of-two than the current allocation —
        i.e. when it can actually shrink the executables' padded bank-word
        input shapes (and therefore pays for invalidating lane maps).
        ``force=True`` compacts whenever any tombstone exists.

        Returns the ``{old lane: new lane}`` remap (the caller must rewrite
        every live plan's lane map), or None when no compaction happened.
        """
        if not self._free:
            return None
        if not force and (
            next_pow2(max(32, self.n_live)) >= self.n_lanes_padded
        ):
            return None
        remap: Dict[int, int] = {}
        rows: List[Optional[Tuple[int, int, int]]] = []
        refs: List[int] = []
        for lane, row in enumerate(self._rows):
            if row is None:
                continue
            remap[lane] = len(rows)
            rows.append(row)
            refs.append(self._refs[lane])
        self._rows, self._refs, self._free = rows, refs, []
        self._table = {row: lane for lane, row in enumerate(rows)}
        self.version += 1
        return remap

    def patterns_padded(self) -> np.ndarray:
        """int32[n_lanes_padded, 3] bank; tombstones/padding never match."""
        out = np.full((self.n_lanes_padded, 3), np.int32(_DEAD_ROW[0]), np.int32)
        for lane, row in enumerate(self._rows):
            if row is not None:
                out[lane] = row
        return out


# encoded lane-id space: real bank lanes are < REFINE_BASE, virtual refined
# lanes are REFINE_BASE + slot (resolved to a dense index only at device
# assembly time, when the current padded real-lane count is known)
REFINE_BASE = 1 << 24

_WC = int(WILDCARD)


def row_subsumes(parent: Tuple[int, int, int], child: Tuple[int, int, int]) -> bool:
    """Pattern-wise term subsumption (the Fedra containment test, per row):
    ``parent`` matches a superset of ``child`` iff every parent slot is
    either a variable (-1) or the same constant as the child's slot.
    Strict (``parent != child``) subsumption additionally needs at least
    one variable-over-constant slot."""
    return all(p == _WC or p == c for p, c in zip(parent, child))


def residual_of(
    parent: Tuple[int, int, int], child: Tuple[int, int, int]
) -> Tuple[int, int, int]:
    """The residual predicate turning parent match bits into child match
    bits: the child's constants in exactly the slots the parent leaves
    variable (wildcard everywhere else). ``child`` ≡ ``parent`` AND
    residual, which is what :func:`repro.kernels.ops.lane_refine`
    evaluates."""
    return tuple(
        c if (p == _WC and c != _WC) else _WC for p, c in zip(parent, child)
    )


class SubsumptionBank:
    """Containment-DAG view over an :class:`IncrementalPatternBank`.

    The plain bank dedups *identical* pattern rows; this wrapper
    additionally recognizes rows that an existing bank row strictly
    subsumes (constant where the parent has a variable, equal elsewhere)
    and registers them as **virtual refined lanes** instead of new bank
    rows: a virtual lane's match bits are its parent lane's bits ANDed
    with a cheap residual predicate over the newly-bound slots
    (:func:`repro.kernels.ops.lane_refine`), so contained interests ride
    the parent's one bank compare instead of widening the shared bank
    pass. Resolution order for each registered row:

    1. exact match against a live bank row  -> shared real lane;
    2. exact match against a live virtual row -> shared virtual lane;
    3. a live bank row strictly subsumes it -> NEW virtual lane (parent =
       the subsuming row with the most bound slots, lowest lane on ties);
    4. otherwise -> new real bank lane.

    The parent edges form a depth-1 containment DAG (virtual rows refine
    real rows only; transitive chains are a ROADMAP follow-on). Every
    virtual row holds a reference on its parent lane, so the parent can
    never be tombstoned from under it. Encoded lane ids returned by
    :meth:`add_plan`: real ids ``< REFINE_BASE``, virtual ids
    ``REFINE_BASE + slot``; :meth:`resolve_lanes` maps them into the
    extended device row space ``[real padded | virtual padded]`` that
    :meth:`patterns_padded` materializes (virtual rows appear there as
    their full child patterns, so the added-side fused match kernel needs
    no refine support — only the shared deleted-side words pass exploits
    the DAG).
    """

    def __init__(self):
        self.bank = IncrementalPatternBank()
        # slot -> (child row, parent real lane, residual row) | None
        self._vrows: List[Optional[tuple]] = []
        self._vrefs: List[int] = []
        self._vfree: List[int] = []
        self._vtable: Dict[Tuple[int, int, int], int] = {}
        self._vversion = 0

    # -- shape/version surface (IncrementalPatternBank-compatible) ----------

    @property
    def version(self) -> int:
        return self.bank.version + self._vversion

    @property
    def n_lanes(self) -> int:
        return self.bank.n_lanes + len(self._vrows)

    @property
    def n_live(self) -> int:
        return self.bank.n_live + len(self._vrows) - len(self._vfree)

    @property
    def n_real(self) -> int:
        return self.bank.n_live

    @property
    def n_virtual(self) -> int:
        return len(self._vrows) - len(self._vfree)

    @property
    def n_real_padded(self) -> int:
        return self.bank.n_lanes_padded

    @property
    def n_virt_padded(self) -> int:
        if not self._vrows:
            return 0
        return next_pow2(max(32, len(self._vrows)))

    @property
    def n_lanes_padded(self) -> int:
        return self.n_real_padded + self.n_virt_padded

    @property
    def n_words(self) -> int:
        return self.n_lanes_padded // 32

    def patterns_padded(self) -> np.ndarray:
        """Extended padded bank: real rows, then virtual rows materialized
        as their full child patterns (dead slots never match)."""
        real = self.bank.patterns_padded()
        if not self._vrows:
            return real
        virt = np.full(
            (self.n_virt_padded, 3), np.int32(_DEAD_ROW[0]), np.int32
        )
        for v, ent in enumerate(self._vrows):
            if ent is not None:
                virt[v] = ent[0]
        return np.concatenate([real, virt], axis=0)

    def real_padded(self) -> np.ndarray:
        """The real-rows-only padded bank (the deleted-side words pass)."""
        return self.bank.patterns_padded()

    def refine_arrays(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """(parents int32[Vp], residual int32[Vp, 3]) for
        :func:`repro.kernels.ops.lane_refine`, or None with no virtual
        rows. Dead slots carry parent -1 (bits forced to zero)."""
        if not self._vrows:
            return None
        vp = self.n_virt_padded
        parents = np.full((vp,), -1, np.int32)
        residual = np.full((vp, 3), np.int32(_DEAD_ROW[0]), np.int32)
        for v, ent in enumerate(self._vrows):
            if ent is not None:
                parents[v] = ent[1]
                residual[v] = ent[2]
        return parents, residual

    def resolve_lanes(self, lanes: Sequence[int]) -> Tuple[int, ...]:
        """Encoded lane ids -> dense extended row indices (valid until the
        next version bump — the padded real-lane count is baked in)."""
        base = self.n_real_padded
        return tuple(
            l if l < REFINE_BASE else base + (l - REFINE_BASE) for l in lanes
        )

    # -- registration --------------------------------------------------------

    def _find_parent(self, key: Tuple[int, int, int]) -> Optional[int]:
        best, best_bound = None, -1
        for lane in range(self.bank.n_lanes):
            row = self.bank.row_of(lane)
            if row is None or row == key:
                continue
            if not row_subsumes(row, key):
                continue
            bound = sum(1 for t in row if t != _WC)
            if bound > best_bound:
                best, best_bound = lane, bound
        return best

    def add_plan(self, plan: CompiledInterest) -> Tuple[int, ...]:
        """Register one plan's rows; returns its encoded lane map."""
        local: List[int] = []
        for j in range(plan.n_total):
            key = (
                int(plan.patterns[j, 0]),
                int(plan.patterns[j, 1]),
                int(plan.patterns[j, 2]),
            )
            if self.bank.lane_of(key) is not None:
                local.append(self.bank.acquire_row(key))
                continue
            v = self._vtable.get(key)
            if v is not None:
                self._vrefs[v] += 1
                local.append(REFINE_BASE + v)
                continue
            parent = self._find_parent(key)
            if parent is None:
                local.append(self.bank.acquire_row(key))
                continue
            self.bank.retain_lane(parent)
            ent = (key, parent, residual_of(self.bank.row_of(parent), key))
            if self._vfree:
                v = self._vfree.pop()
                self._vrows[v] = ent
                self._vrefs[v] = 1
            else:
                v = len(self._vrows)
                self._vrows.append(ent)
                self._vrefs.append(1)
            self._vtable[key] = v
            self._vversion += 1
            local.append(REFINE_BASE + v)
        return tuple(local)

    def remove_plan(self, lanes: Sequence[int]) -> None:
        for lane in lanes:
            if lane < REFINE_BASE:
                self.bank.release_row(lane)
                continue
            v = lane - REFINE_BASE
            self._vrefs[v] -= 1
            if self._vrefs[v] == 0:
                key, parent, _ = self._vrows[v]
                del self._vtable[key]
                self._vrows[v] = None
                self._vfree.append(v)
                self.bank.release_row(parent)
                self._vversion += 1
            elif self._vrefs[v] < 0:
                raise ValueError(
                    f"virtual lane {v} released more than acquired"
                )

    def maybe_compact(self, force: bool = False) -> Optional[Dict[int, int]]:
        """Compact real and virtual lane spaces when that shrinks their
        padded device shapes (same rule as the plain bank). Returns a
        TOTAL encoded remap over every live lane id (identity entries
        included), or None when nothing moved."""
        live_real_old = self.bank.live_lanes()
        remap_r = self.bank.maybe_compact(force)
        if remap_r is not None:
            for v, ent in enumerate(self._vrows):
                if ent is not None:
                    key, parent, residual = ent
                    self._vrows[v] = (key, remap_r[parent], residual)
            self._vversion += 1
        remap_v = None
        if self._vfree:
            live = len(self._vrows) - len(self._vfree)
            new_pad = next_pow2(max(32, live)) if live else 0
            if force or new_pad < self.n_virt_padded:
                remap_v = {}
                rows, refs = [], []
                for v, ent in enumerate(self._vrows):
                    if ent is None:
                        continue
                    remap_v[v] = len(rows)
                    rows.append(ent)
                    refs.append(self._vrefs[v])
                self._vrows, self._vrefs, self._vfree = rows, refs, []
                self._vtable = {
                    ent[0]: v for v, ent in enumerate(rows)
                }
                self._vversion += 1
        if remap_r is None and remap_v is None:
            return None
        out: Dict[int, int] = (
            dict(remap_r)
            if remap_r is not None
            else {lane: lane for lane in live_real_old}
        )
        if remap_v is not None:
            for old, new in remap_v.items():
                out[REFINE_BASE + old] = REFINE_BASE + new
        else:
            for key in self._vtable:
                v = self._vtable[key]
                out[REFINE_BASE + v] = REFINE_BASE + v
        return out


class InterestCompileError(ValueError):
    pass


def _pattern_vars(p: TriplePattern) -> List[Tuple[str, int]]:
    return [(t, i) for i, t in enumerate(p.slots()) if is_var(t)]


def compile_interest(expr: InterestExpr, dictionary: Dictionary) -> CompiledInterest:
    all_patterns = list(expr.bgp) + list(expr.ogp)
    n_bgp, n_ogp = len(expr.bgp), len(expr.ogp)
    if n_bgp == 0:
        raise InterestCompileError("BGP must contain at least one triple pattern")
    if n_bgp + n_ogp > 32:
        raise InterestCompileError("at most 32 triple patterns per interest")

    # variable occurrence census over BGP + OGP
    occ: Dict[str, List[Tuple[int, int]]] = {}
    for j, p in enumerate(all_patterns):
        for v, slot in _pattern_vars(p):
            occ.setdefault(v, []).append((j, slot))

    join_vars = {v for v, sites in occ.items() if len(sites) >= 2}
    for v in join_vars:
        for j, slot in occ[v]:
            if slot == 1:
                raise InterestCompileError(
                    f"join variable {v} in predicate position of pattern {j} "
                    "is unsupported"
                )

    # connectivity of the BGP via shared variables (Definition 3)
    if n_bgp > 1:
        adj = {i: set() for i in range(n_bgp)}
        for v, sites in occ.items():
            bgp_sites = [j for j, _ in sites if j < n_bgp]
            for a in bgp_sites:
                for b in bgp_sites:
                    if a != b:
                        adj[a].add(b)
        seen = {0}
        stack = [0]
        while stack:
            for nb in adj[stack.pop()]:
                if nb not in seen:
                    seen.add(nb)
                    stack.append(nb)
        if len(seen) != n_bgp:
            raise InterestCompileError("BGP is disjoint (Definition 3 violated)")

    # root selection: most-connected join variable in the BGP
    def bgp_degree(v: str) -> int:
        return sum(1 for j, _ in occ[v] if j < n_bgp)

    if join_vars:
        root = max(sorted(join_vars), key=bgp_degree)
    else:
        # single-pattern (or variable-free) BGP: group by the subject slot
        root = expr.bgp[0].s if is_var(expr.bgp[0].s) else ""

    kinds: List[str] = []
    anchor_slot: List[int] = []
    child_slot: List[int] = []
    child_var_of: List[int] = []
    eq_pairs: List[Optional[Tuple[int, int]]] = []
    child_vars: List[str] = []

    def child_index(v: str) -> int:
        if v not in child_vars:
            child_vars.append(v)
        return child_vars.index(v)

    for j, p in enumerate(all_patterns):
        pvars = _pattern_vars(p)
        jvars = [(v, slot) for v, slot in pvars if v in join_vars]
        pv_names = [v for v, _ in pvars]
        eq: Optional[Tuple[int, int]] = None
        for v in set(pv_names):
            sites = [slot for name, slot in pvars if name == v]
            if len(sites) == 2:
                eq = (sites[0], sites[1])
            elif len(sites) > 2:
                raise InterestCompileError("variable repeated 3x in one pattern")
        eq_pairs.append(eq)

        root_sites = [slot for v, slot in jvars if v == root]
        other = [(v, slot) for v, slot in jvars if v != root]
        if root_sites and other:
            if len(other) > 1:
                raise InterestCompileError(
                    f"pattern {j} links three join variables (not a tree)"
                )
            cv, cslot = other[0]
            kinds.append("edge")
            anchor_slot.append(root_sites[0])
            child_slot.append(cslot)
            child_var_of.append(child_index(cv))
        elif root_sites:
            kinds.append("root")
            anchor_slot.append(root_sites[0])
            child_slot.append(-1)
            child_var_of.append(-1)
        elif other:
            if len({v for v, _ in other}) > 1:
                raise InterestCompileError(
                    f"pattern {j} joins two non-root variables: query tree "
                    "depth > 2 is unsupported"
                )
            cv, cslot = other[0]
            kinds.append("child")
            anchor_slot.append(cslot)
            child_slot.append(-1)
            child_var_of.append(child_index(cv))
        else:
            # no join variable: only legal for a single-pattern BGP or
            # OGP patterns anchored at the (constant) root subject
            if root == "" or (j >= n_bgp and not join_vars) or n_bgp == 1:
                kinds.append("root")
                anchor_slot.append(0)
                child_slot.append(-1)
                child_var_of.append(-1)
            else:
                raise InterestCompileError(
                    f"pattern {j} shares no join variable with the BGP root"
                )

    # every child variable must carry at least one edge to the root
    for ci, cv in enumerate(child_vars):
        edges = [j for j in range(len(all_patterns))
                 if kinds[j] == "edge" and child_var_of[j] == ci]
        if not edges:
            raise InterestCompileError(
                f"child variable {cv} is not linked to root {root}"
            )

    # encode constants
    pat = np.full((len(all_patterns), 3), WILDCARD, dtype=np.int32)
    for j, p in enumerate(all_patterns):
        for k, term in enumerate(p.slots()):
            if not is_var(term):
                pat[j, k] = dictionary.encode_term(term)

    return CompiledInterest(
        patterns=pat,
        n_bgp=n_bgp,
        n_ogp=n_ogp,
        kinds=tuple(kinds),
        anchor_slot=tuple(anchor_slot),
        child_slot=tuple(child_slot),
        child_var=tuple(child_var_of),
        eq_pairs=tuple(eq_pairs),
        root_var=root,
        child_vars=tuple(child_vars),
        source=expr.source,
        target=expr.target,
    )
