"""Interest expressions (Definition 7) and their compilation to static plans.

An interest expression ``i_g = <τ, b, op>`` is compiled into a
``CompiledInterest``: dictionary-encoded pattern tensors plus a static query
plan (root variable, child stars, edge patterns) that the jitted evaluator in
:mod:`repro.core.evaluation` closes over.

Supported BGP shape (covers both paper evaluation queries and the running
example): connected patterns whose join graph is a tree of depth <= 2
(one root variable + any number of child variables each linked to the root by
one or more edge patterns). Join variables in predicate position and cyclic
join graphs are rejected at compile time (DESIGN.md §1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dictionary import Dictionary
from .triples import WILDCARD

SLOT_NAMES = ("subject", "predicate", "object")


def is_var(term: str) -> bool:
    return term.startswith("?")


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    s: str
    p: str
    o: str

    def slots(self) -> Tuple[str, str, str]:
        return (self.s, self.p, self.o)


@dataclasses.dataclass(frozen=True)
class InterestExpr:
    """i_g = <source g, target τ, BGP b, OGP op> (Definition 7)."""

    source: str
    target: str
    bgp: Tuple[TriplePattern, ...]
    ogp: Tuple[TriplePattern, ...] = ()

    @staticmethod
    def parse(source: str, target: str, bgp: Sequence[Tuple[str, str, str]],
              ogp: Sequence[Tuple[str, str, str]] = ()) -> "InterestExpr":
        return InterestExpr(
            source=source,
            target=target,
            bgp=tuple(TriplePattern(*t) for t in bgp),
            ogp=tuple(TriplePattern(*t) for t in ogp),
        )


@dataclasses.dataclass(frozen=True)
class CompiledInterest:
    """Static evaluation plan for one interest expression.

    Pattern order: BGP patterns first, then OGP patterns. Per-pattern kind:
    ``root``  — anchored at the root variable (star pattern, incl. const-root)
    ``edge``  — links root variable to a child variable
    ``child`` — anchored at a child variable (subtree star)
    """

    patterns: np.ndarray  # (n_total, 3) int32; -1 where the slot is a variable
    n_bgp: int
    n_ogp: int
    kinds: Tuple[str, ...]
    anchor_slot: Tuple[int, ...]  # grouping slot (root-side slot for edges)
    child_slot: Tuple[int, ...]  # edge: slot of the child var; else -1
    child_var: Tuple[int, ...]  # edge/child patterns: child var index; else -1
    eq_pairs: Tuple[Optional[Tuple[int, int]], ...]  # repeated-var-in-pattern
    root_var: str
    child_vars: Tuple[str, ...]
    source: str
    target: str

    @property
    def n_total(self) -> int:
        return self.n_bgp + self.n_ogp

    @property
    def n_children(self) -> int:
        return len(self.child_vars)

    def bgp_ids(self) -> range:
        return range(self.n_bgp)

    def child_bgp_patterns(self, cvar: int) -> List[int]:
        return [
            j for j in range(self.n_bgp)
            if self.kinds[j] == "child" and self.child_var[j] == cvar
        ]

    def child_edges(self, cvar: int) -> List[int]:
        return [
            j for j in range(self.n_bgp)
            if self.kinds[j] == "edge" and self.child_var[j] == cvar
        ]


@dataclasses.dataclass(frozen=True)
class PatternBank:
    """Consolidated triple-pattern bank shared by many compiled interests.

    Distinct (s, p, o) pattern rows across all registered interests are
    deduplicated into one bank; each plan keeps a static lane map from its
    local pattern index to the bank lane carrying that pattern's match bit.
    A pattern shared by K interests is evaluated once per changeset pass and
    its bit fanned out K ways (kernels.ops.lane_bits). Per-pattern
    constraints that are *not* functions of the raw (s, p, o) row alone —
    the repeated-variable ``eq_pairs`` masks — stay per-plan downstream, so
    dedup by row is exact.
    """

    patterns: np.ndarray  # (n_lanes, 3) int32; -1 where the slot is a variable
    lanes: Tuple[Tuple[int, ...], ...]  # per plan: local pattern j -> bank lane

    @property
    def n_lanes(self) -> int:
        return int(self.patterns.shape[0])

    @property
    def n_words(self) -> int:
        """uint32 bitset words needed to carry every lane (chunking unit)."""
        return max(1, -(-self.n_lanes // 32))


def build_pattern_bank(plans: Sequence[CompiledInterest]) -> PatternBank:
    """Dedup the patterns of many plans into one bank with lane maps."""
    table: Dict[Tuple[int, int, int], int] = {}
    rows: List[Tuple[int, int, int]] = []
    lanes: List[Tuple[int, ...]] = []
    for plan in plans:
        local: List[int] = []
        for j in range(plan.n_total):
            key = (
                int(plan.patterns[j, 0]),
                int(plan.patterns[j, 1]),
                int(plan.patterns[j, 2]),
            )
            if key not in table:
                table[key] = len(rows)
                rows.append(key)
            local.append(table[key])
        lanes.append(tuple(local))
    pat = np.asarray(rows, dtype=np.int32).reshape(len(rows), 3)
    return PatternBank(patterns=pat, lanes=tuple(lanes))


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1). The padding rule shared by
    cohort sizes, bank lane counts, and batch capacities: power-of-two
    shapes are what lets churn reuse cached executables."""
    return 1 << max(0, n - 1).bit_length()


# A bank row that matches nothing: every slot is the PAD sentinel, which no
# dictionary-encoded triple can carry (ids are dense and < 2**31 - 1) and
# which the matchers additionally exclude via the valid-row mask. Used for
# tombstoned lanes and for padding the bank to a stable device shape.
_DEAD_ROW = (int(np.iinfo(np.int32).max),) * 3


class IncrementalPatternBank:
    """Mutable pattern bank with *stable* lane numbering under churn.

    :func:`build_pattern_bank` assigns lanes by rebuilding the whole table,
    so any subscription change renumbers every plan's lane map and — because
    lane maps and the bank array feed the broker's compiled cohort steps —
    invalidates executables that had nothing to do with the change. This
    class makes the bank an incremental structure instead:

    * ``add_plan`` dedups against the live table and extends the bank only
      with genuinely new rows; existing lanes are never renumbered.
    * ``remove_plan`` decrements per-lane refcounts; lanes that drop to zero
      are *tombstoned* (their row becomes the never-matching ``_DEAD_ROW``)
      rather than removed, so every other plan's lane map stays valid.
      Tombstoned lanes are reused first by later ``add_plan`` calls, which
      keeps re-subscription churn from growing the bank at all.
    * ``maybe_compact`` renumbers only when doing so would actually shrink
      the padded device bank shape (the padded-word boundary) — the caller
      applies the returned remap to all live lane maps. Tombstone *count*
      is irrelevant on its own: the bank array is padded to a power of two
      and executables key on that padded shape, so a compaction that lands
      in the same padded bucket would churn every live lane map (and every
      cached static-array signature) for zero executable-shape benefit.

    ``patterns_padded`` pads the lane count to a power of two (min 32, i.e.
    whole uint32 bitset words) so the bank's *device shape* — part of every
    cohort executable's input signature — changes only when the bank crosses
    a power-of-two boundary, not on every subscription.

    ``version`` increments whenever the padded array contents change; the
    broker uses it to refresh its device copy cheaply.
    """

    def __init__(self):
        self._table: Dict[Tuple[int, int, int], int] = {}
        self._rows: List[Optional[Tuple[int, int, int]]] = []
        self._refs: List[int] = []
        self._free: List[int] = []  # tombstoned lanes, reused LIFO
        self.version = 0

    @property
    def n_lanes(self) -> int:
        """Allocated lanes, including tombstones (the padded-shape driver)."""
        return len(self._rows)

    @property
    def n_live(self) -> int:
        return len(self._rows) - len(self._free)

    @property
    def n_words(self) -> int:
        return max(1, -(-len(self._rows) // 32))

    @property
    def n_lanes_padded(self) -> int:
        """Power-of-two (>= 32) lane count of :meth:`patterns_padded`."""
        return next_pow2(max(32, len(self._rows)))

    def add_plan(self, plan: CompiledInterest) -> Tuple[int, ...]:
        """Register one plan's patterns; returns its (stable) lane map."""
        local: List[int] = []
        for j in range(plan.n_total):
            key = (
                int(plan.patterns[j, 0]),
                int(plan.patterns[j, 1]),
                int(plan.patterns[j, 2]),
            )
            lane = self._table.get(key)
            if lane is None:
                if self._free:
                    lane = self._free.pop()
                    self._rows[lane] = key
                    self._refs[lane] = 0
                else:
                    lane = len(self._rows)
                    self._rows.append(key)
                    self._refs.append(0)
                self._table[key] = lane
                self.version += 1
            self._refs[lane] += 1
            local.append(lane)
        return tuple(local)

    def remove_plan(self, lanes: Sequence[int]) -> None:
        """Release one plan's lanes (symmetric with :meth:`add_plan`)."""
        for lane in lanes:
            self._refs[lane] -= 1
            if self._refs[lane] == 0:
                del self._table[self._rows[lane]]
                self._rows[lane] = None
                self._free.append(lane)
                self.version += 1
            elif self._refs[lane] < 0:
                raise ValueError(f"lane {lane} released more than acquired")

    def maybe_compact(self, force: bool = False) -> Optional[Dict[int, int]]:
        """Renumber away tombstones when that shrinks the padded bank shape.

        Compaction is driven by the padded-word boundary, not the raw
        tombstone fraction: it runs exactly when the live lanes would pad
        to a strictly smaller power-of-two than the current allocation —
        i.e. when it can actually shrink the executables' padded bank-word
        input shapes (and therefore pays for invalidating lane maps).
        ``force=True`` compacts whenever any tombstone exists.

        Returns the ``{old lane: new lane}`` remap (the caller must rewrite
        every live plan's lane map), or None when no compaction happened.
        """
        if not self._free:
            return None
        if not force and (
            next_pow2(max(32, self.n_live)) >= self.n_lanes_padded
        ):
            return None
        remap: Dict[int, int] = {}
        rows: List[Optional[Tuple[int, int, int]]] = []
        refs: List[int] = []
        for lane, row in enumerate(self._rows):
            if row is None:
                continue
            remap[lane] = len(rows)
            rows.append(row)
            refs.append(self._refs[lane])
        self._rows, self._refs, self._free = rows, refs, []
        self._table = {row: lane for lane, row in enumerate(rows)}
        self.version += 1
        return remap

    def patterns_padded(self) -> np.ndarray:
        """int32[n_lanes_padded, 3] bank; tombstones/padding never match."""
        out = np.full((self.n_lanes_padded, 3), np.int32(_DEAD_ROW[0]), np.int32)
        for lane, row in enumerate(self._rows):
            if row is not None:
                out[lane] = row
        return out


class InterestCompileError(ValueError):
    pass


def _pattern_vars(p: TriplePattern) -> List[Tuple[str, int]]:
    return [(t, i) for i, t in enumerate(p.slots()) if is_var(t)]


def compile_interest(expr: InterestExpr, dictionary: Dictionary) -> CompiledInterest:
    all_patterns = list(expr.bgp) + list(expr.ogp)
    n_bgp, n_ogp = len(expr.bgp), len(expr.ogp)
    if n_bgp == 0:
        raise InterestCompileError("BGP must contain at least one triple pattern")
    if n_bgp + n_ogp > 32:
        raise InterestCompileError("at most 32 triple patterns per interest")

    # variable occurrence census over BGP + OGP
    occ: Dict[str, List[Tuple[int, int]]] = {}
    for j, p in enumerate(all_patterns):
        for v, slot in _pattern_vars(p):
            occ.setdefault(v, []).append((j, slot))

    join_vars = {v for v, sites in occ.items() if len(sites) >= 2}
    for v in join_vars:
        for j, slot in occ[v]:
            if slot == 1:
                raise InterestCompileError(
                    f"join variable {v} in predicate position of pattern {j} "
                    "is unsupported"
                )

    # connectivity of the BGP via shared variables (Definition 3)
    if n_bgp > 1:
        adj = {i: set() for i in range(n_bgp)}
        for v, sites in occ.items():
            bgp_sites = [j for j, _ in sites if j < n_bgp]
            for a in bgp_sites:
                for b in bgp_sites:
                    if a != b:
                        adj[a].add(b)
        seen = {0}
        stack = [0]
        while stack:
            for nb in adj[stack.pop()]:
                if nb not in seen:
                    seen.add(nb)
                    stack.append(nb)
        if len(seen) != n_bgp:
            raise InterestCompileError("BGP is disjoint (Definition 3 violated)")

    # root selection: most-connected join variable in the BGP
    def bgp_degree(v: str) -> int:
        return sum(1 for j, _ in occ[v] if j < n_bgp)

    if join_vars:
        root = max(sorted(join_vars), key=bgp_degree)
    else:
        # single-pattern (or variable-free) BGP: group by the subject slot
        root = expr.bgp[0].s if is_var(expr.bgp[0].s) else ""

    kinds: List[str] = []
    anchor_slot: List[int] = []
    child_slot: List[int] = []
    child_var_of: List[int] = []
    eq_pairs: List[Optional[Tuple[int, int]]] = []
    child_vars: List[str] = []

    def child_index(v: str) -> int:
        if v not in child_vars:
            child_vars.append(v)
        return child_vars.index(v)

    for j, p in enumerate(all_patterns):
        pvars = _pattern_vars(p)
        jvars = [(v, slot) for v, slot in pvars if v in join_vars]
        pv_names = [v for v, _ in pvars]
        eq: Optional[Tuple[int, int]] = None
        for v in set(pv_names):
            sites = [slot for name, slot in pvars if name == v]
            if len(sites) == 2:
                eq = (sites[0], sites[1])
            elif len(sites) > 2:
                raise InterestCompileError("variable repeated 3x in one pattern")
        eq_pairs.append(eq)

        root_sites = [slot for v, slot in jvars if v == root]
        other = [(v, slot) for v, slot in jvars if v != root]
        if root_sites and other:
            if len(other) > 1:
                raise InterestCompileError(
                    f"pattern {j} links three join variables (not a tree)"
                )
            cv, cslot = other[0]
            kinds.append("edge")
            anchor_slot.append(root_sites[0])
            child_slot.append(cslot)
            child_var_of.append(child_index(cv))
        elif root_sites:
            kinds.append("root")
            anchor_slot.append(root_sites[0])
            child_slot.append(-1)
            child_var_of.append(-1)
        elif other:
            if len({v for v, _ in other}) > 1:
                raise InterestCompileError(
                    f"pattern {j} joins two non-root variables: query tree "
                    "depth > 2 is unsupported"
                )
            cv, cslot = other[0]
            kinds.append("child")
            anchor_slot.append(cslot)
            child_slot.append(-1)
            child_var_of.append(child_index(cv))
        else:
            # no join variable: only legal for a single-pattern BGP or
            # OGP patterns anchored at the (constant) root subject
            if root == "" or (j >= n_bgp and not join_vars) or n_bgp == 1:
                kinds.append("root")
                anchor_slot.append(0)
                child_slot.append(-1)
                child_var_of.append(-1)
            else:
                raise InterestCompileError(
                    f"pattern {j} shares no join variable with the BGP root"
                )

    # every child variable must carry at least one edge to the root
    for ci, cv in enumerate(child_vars):
        edges = [j for j in range(len(all_patterns))
                 if kinds[j] == "edge" and child_var_of[j] == ci]
        if not edges:
            raise InterestCompileError(
                f"child variable {cv} is not linked to root {root}"
            )

    # encode constants
    pat = np.full((len(all_patterns), 3), WILDCARD, dtype=np.int32)
    for j, p in enumerate(all_patterns):
        for k, term in enumerate(p.slots()):
            if not is_var(term):
                pat[j, k] = dictionary.encode_term(term)

    return CompiledInterest(
        patterns=pat,
        n_bgp=n_bgp,
        n_ogp=n_ogp,
        kinds=tuple(kinds),
        anchor_slot=tuple(anchor_slot),
        child_slot=tuple(child_slot),
        child_var=tuple(child_var_of),
        eq_pairs=tuple(eq_pairs),
        root_var=root,
        child_vars=tuple(child_vars),
        source=expr.source,
        target=expr.target,
    )
