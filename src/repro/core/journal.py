"""Append-only write-ahead changeset journal: CRC-framed segment files.

The broker's durability substrate (module docstring of
:mod:`repro.core.broker`, durability layer): every state-changing broker
event — ingested changeset, subscribe/unsubscribe, committed fire — is one
sequence-numbered record appended *before* (ingest/subscribe) or *at the
commit point of* (fire) the in-memory effect, so
:meth:`repro.core.broker.Broker.recover` can rebuild the exact broker
state by snapshot-plus-tail-replay.

**Record framing.** A journal is a directory of segment files named
``wal_<first-seq>.seg``. Each segment starts with an 8-byte header
(``RJNL`` magic + little-endian u32 format version) followed by frames::

    [u32 payload_len][u32 crc32(payload)][payload]

    payload = [u32 header_len][header JSON][array blobs...]

The header JSON carries ``seq`` (monotonically increasing, globally unique
across segments), ``kind`` (``subscribe`` / ``unsubscribe`` / ``ingest`` /
``fire``), any record metadata, and an ``arrays`` manifest of
``[name, dtype, shape]`` entries; the blobs are the named arrays'
C-contiguous bytes concatenated in manifest order. Everything needed to
decode a record is inside its own frame — a reader never needs a side
index.

**Truncation rules (torn-tail recovery).** A crash can leave at most a
*suffix* of the byte stream unwritten or garbled, so on open the journal
scans segments in sequence order and stops at the first bad frame: a
partial length/CRC prefix, a frame extending past end-of-file, a CRC
mismatch, or an undecodable payload. The bad frame and everything after it
— including all later segments — are *physically discarded* (the torn
segment is truncated at the last good frame; later segments are unlinked),
never reinterpreted: a record is durable if and only if its complete frame
checksums, and ``last_seq`` reflects exactly the durable prefix.
``dropped_bytes`` reports how much tail was discarded, so recovery can
surface torn writes without failing.

**fsync-on-commit.** With ``fsync=True`` (the default) every
:meth:`append` flushes and fsyncs before returning — an acknowledged
append survives process death. ``fsync=False`` trades that for ingest
throughput (the OS page cache decides); the broker's recovery discipline
is unchanged either way, only the durable prefix may be shorter.

**Rotation + compaction.** A segment that has grown past
``segment_bytes`` is closed and a new one named by the next record's seq
is started, so old records age out in whole-file units:
:meth:`compact` unlinks every segment whose records all precede
``keep_from_seq`` (the broker passes ``min(min live subscriber frontier,
last snapshot seq + 1)`` — see
:meth:`repro.core.broker.Broker.compact_journal`), which is safe because
replay needs only (a) records after the last snapshot and (b) ingest
records at or after the oldest live consumption frontier.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

_MAGIC = b"RJNL"
_VERSION = 1
_HEADER = _MAGIC + struct.pack("<I", _VERSION)
_FRAME = struct.Struct("<II")  # payload length, crc32(payload)


def _segment_name(first_seq: int) -> str:
    return f"wal_{first_seq:012d}.seg"


def _segment_first_seq(path: Path) -> int:
    return int(path.name.split("_")[1].split(".")[0])


@dataclass
class JournalRecord:
    """One decoded journal record."""

    seq: int
    kind: str
    meta: Dict
    arrays: Dict[str, np.ndarray] = field(default_factory=dict)


def encode_record(
    seq: int,
    kind: str,
    meta: Optional[Dict] = None,
    arrays: Optional[Dict[str, np.ndarray]] = None,
) -> bytes:
    """One record as a complete frame (length + CRC + payload)."""
    manifest = []
    blobs = []
    for name in sorted(arrays or {}):
        a = np.ascontiguousarray(arrays[name])
        manifest.append([name, a.dtype.str, list(a.shape)])
        blobs.append(a.tobytes())
    head = dict(meta or {})
    head["seq"] = int(seq)
    head["kind"] = str(kind)
    head["arrays"] = manifest
    hb = json.dumps(head, separators=(",", ":")).encode()
    payload = struct.pack("<I", len(hb)) + hb + b"".join(blobs)
    return _FRAME.pack(len(payload), zlib.crc32(payload)) + payload


def decode_payload(payload: bytes) -> JournalRecord:
    (hlen,) = struct.unpack_from("<I", payload, 0)
    head = json.loads(payload[4 : 4 + hlen].decode())
    off = 4 + hlen
    arrays: Dict[str, np.ndarray] = {}
    for name, dt, shape in head.pop("arrays", []):
        dtype = np.dtype(dt)
        count = int(np.prod(shape, dtype=np.int64)) if shape else 1
        arr = np.frombuffer(payload, dtype=dtype, count=count, offset=off)
        arrays[name] = arr.reshape(shape).copy()
        off += count * dtype.itemsize
    if off != len(payload):
        raise ValueError("payload length does not match array manifest")
    return JournalRecord(
        seq=int(head.pop("seq")), kind=head.pop("kind"), meta=head,
        arrays=arrays,
    )


def scan_segment(path: Path) -> Tuple[List[Tuple[int, int, int, str]], int, int]:
    """Validate one segment: ``(entries, good_end, total_bytes)``.

    ``entries`` is ``[(offset, end_offset, seq, kind)]`` for every intact
    frame in order; ``good_end`` is the byte offset of the first bad frame
    (== ``total_bytes`` when the segment is clean). A bad header yields
    ``good_end == 0``: the whole segment is unusable.
    """
    data = Path(path).read_bytes()
    total = len(data)
    if total < len(_HEADER) or data[: len(_HEADER)] != _HEADER:
        return [], 0, total
    entries: List[Tuple[int, int, int, str]] = []
    off = len(_HEADER)
    while off + _FRAME.size <= total:
        length, crc = _FRAME.unpack_from(data, off)
        end = off + _FRAME.size + length
        if length < 4 or end > total:
            break
        payload = data[off + _FRAME.size : end]
        if zlib.crc32(payload) != crc:
            break
        try:
            rec = decode_payload(payload)
        except Exception:
            break
        entries.append((off, end, rec.seq, rec.kind))
        off = end
    return entries, off, total


class ChangesetJournal:
    """Segmented append-only WAL with torn-tail truncation on open.

    ``last_seq`` is the highest durable sequence number (0 when empty).
    Appends must carry strictly increasing seqs; the broker owns the clock
    and passes its unified sequence explicitly, while standalone use may
    omit ``seq`` to auto-increment.
    """

    def __init__(
        self,
        directory: str | Path,
        *,
        fsync: bool = True,
        segment_bytes: int = 4 << 20,
    ):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        self.segment_bytes = int(segment_bytes)
        self.last_seq = 0
        self.dropped_bytes = 0  # torn/corrupt tail discarded on open
        self.torn = False
        self._fh = None
        self._segments: List[Path] = sorted(
            self.dir.glob("wal_*.seg"), key=_segment_first_seq
        )
        self._open_scan()

    # -- open-time recovery -------------------------------------------------

    def _open_scan(self) -> None:
        kept: List[Path] = []
        truncated = False
        for seg in self._segments:
            if truncated:
                # nothing after a torn point is reachable: the seq chain is
                # broken, so later segments are discarded wholesale
                self.dropped_bytes += seg.stat().st_size
                seg.unlink()
                continue
            entries, good_end, total = scan_segment(seg)
            if good_end == 0:
                # unusable header — treat like a fully torn segment
                truncated = True
                self.torn = True
                self.dropped_bytes += total
                seg.unlink()
                continue
            if good_end < total:
                truncated = True
                self.torn = True
                self.dropped_bytes += total - good_end
                with open(seg, "r+b") as f:
                    f.truncate(good_end)
            if entries:
                self.last_seq = entries[-1][2]
            kept.append(seg)
        self._segments = kept

    # -- append path --------------------------------------------------------

    def _writer(self, seq: int):
        if self._fh is not None and self._fh.tell() >= self.segment_bytes:
            self._fh.close()
            self._fh = None
        if self._fh is None:
            if (
                self._segments
                and self._segments[-1].stat().st_size < self.segment_bytes
            ):
                self._fh = open(self._segments[-1], "ab")
            else:
                path = self.dir / _segment_name(seq)
                self._fh = open(path, "ab")
                if self._fh.tell() == 0:
                    self._fh.write(_HEADER)
                self._segments.append(path)
        return self._fh

    def append(
        self,
        kind: str,
        meta: Optional[Dict] = None,
        arrays: Optional[Dict[str, np.ndarray]] = None,
        seq: Optional[int] = None,
    ) -> int:
        """Append one record durably; returns its seq."""
        if seq is None:
            seq = self.last_seq + 1
        if seq <= self.last_seq:
            raise ValueError(
                f"journal seq must increase: got {seq}, last {self.last_seq}"
            )
        frame = encode_record(seq, kind, meta, arrays)
        fh = self._writer(seq)
        fh.write(frame)
        fh.flush()
        if self.fsync:
            os.fsync(fh.fileno())
        self.last_seq = seq
        return seq

    def sync(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- read path ----------------------------------------------------------

    @property
    def segments(self) -> List[Path]:
        return list(self._segments)

    def records(self, start_seq: int = 1) -> Iterator[JournalRecord]:
        """Decoded records with ``seq >= start_seq``, in seq order."""
        self.close()  # flush buffered writes before re-reading files
        for seg in list(self._segments):
            data = seg.read_bytes()
            off = len(_HEADER)
            total = len(data)
            while off + _FRAME.size <= total:
                length, _ = _FRAME.unpack_from(data, off)
                end = off + _FRAME.size + length
                rec = decode_payload(data[off + _FRAME.size : end])
                if rec.seq >= start_seq:
                    yield rec
                off = end

    def compact(self, keep_from_seq: int) -> int:
        """Unlink whole segments whose records all precede ``keep_from_seq``.

        A segment is droppable exactly when the *next* segment's first seq
        is <= ``keep_from_seq`` (segments hold contiguous seq ranges named
        by their first record); the newest segment is always kept. Returns
        the number of segments removed.
        """
        removed = 0
        while len(self._segments) >= 2:
            if _segment_first_seq(self._segments[1]) <= keep_from_seq:
                seg = self._segments.pop(0)
                seg.unlink()
                removed += 1
            else:
                break
        return removed
