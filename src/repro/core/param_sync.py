"""Beyond-paper: interest-based update propagation for the MODEL plane.

DESIGN.md §Arch-applicability: the paper's mechanism is data-plane, but the
same subscribe/filter/propagate split applies to sparsely-updated parameter
banks — MoE expert blocks and embedding rows. A trainer publishes per-step
*parameter changesets* (row indices + new values for rows whose update
exceeded a threshold); each serving replica registers a row-set interest
(the experts it hosts, its hot vocab rows) and applies only the interesting
slice — the iRap split of interesting / uninteresting applied to weights.

For dense (non-row-sparse) banks this degenerates to full mirroring, which
the API makes explicit (``interest=None``). Wire format mirrors the RDF
changeset: ⟨removed, added⟩ becomes ⟨rows, values⟩ (updates are total per
row, so no remove side is needed).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamChangeset:
    """Row-sparse update to one parameter bank (rows indexed on axis 0)."""

    bank: str
    rows: jax.Array  # int32[K] row indices (PAD-free)
    values: jax.Array  # [K, ...] new row contents

    @property
    def nbytes(self) -> int:
        return int(self.values.size * self.values.dtype.itemsize
                   + self.rows.size * 4)


def diff_bank(
    bank: str, old: jax.Array, new: jax.Array, *, atol: float = 0.0
) -> ParamChangeset:
    """Publish the rows of ``new`` that changed (per-row max-abs > atol)."""
    flat_old = old.reshape(old.shape[0], -1)
    flat_new = new.reshape(new.shape[0], -1)
    changed = jnp.max(jnp.abs(flat_new - flat_old), axis=1) > atol
    idx = jnp.nonzero(changed)[0].astype(jnp.int32)  # host-side sync point
    return ParamChangeset(bank=bank, rows=idx, values=new[idx])


def filter_changeset(
    cs: ParamChangeset, interest_rows: Optional[jax.Array]
) -> ParamChangeset:
    """Keep only rows the replica subscribed to (None = mirror everything)."""
    if interest_rows is None:
        return cs
    member = jnp.isin(cs.rows, interest_rows)
    keep = jnp.nonzero(member)[0]
    return ParamChangeset(bank=cs.bank, rows=cs.rows[keep], values=cs.values[keep])


def apply_changeset(bank_value: jax.Array, cs: ParamChangeset) -> jax.Array:
    return bank_value.at[cs.rows].set(cs.values)


class ParamReplica:
    """A serving replica holding interest-filtered parameter banks."""

    def __init__(
        self,
        banks: Dict[str, jax.Array],
        interests: Dict[str, Optional[jax.Array]],
    ):
        self.banks = dict(banks)
        self.interests = interests
        self.bytes_received = 0
        self.bytes_offered = 0

    def receive(self, cs: ParamChangeset) -> None:
        self.bytes_offered += cs.nbytes
        mine = filter_changeset(cs, self.interests.get(cs.bank))
        self.bytes_received += mine.nbytes
        self.banks[cs.bank] = apply_changeset(self.banks[cs.bank], mine)

    @property
    def savings(self) -> float:
        return 1.0 - self.bytes_received / max(self.bytes_offered, 1)
