"""Interest evaluation combination and update propagation (Defs 6, 13-18).

``make_interest_step`` builds the fully jitted per-changeset step for one
interest expression:

    d(i, D)        -> <r, r_i, r'>          (Def 13, over deleted triples)
    α(i, A ∪ ρ)    -> <a, a_i, a'>          (Def 14, over added ∪ potential)
    Δ(τ) = <r ∪ r', a>                      (Def 16)
    Δ(ρ) = <r_i, a_i ∪ r'>                  (Def 17)
    Υ: τ' = (τ \\ (r ∪ r')) ∪ a             (Def 18)
       ρ' = ((ρ \\ r_i) ∪ a_i ∪ r') \\ a    (Def 17 + promotion fix, DESIGN §1)

The host-side :class:`IrapEngine` owns the capacities, re-jits on overflow
(store growth) or dictionary growth, and exposes per-changeset statistics —
the production control loop around the pure functional core.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dictionary import Dictionary
from .evaluation import SideResult, TripleIndex, build_index, make_side_evaluator
from .interest import CompiledInterest, InterestExpr, compile_interest
from .triples import PAD, TripleStore, difference, empty, from_array, union


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["r", "r_i", "r_prime", "a", "a_i", "overflow"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class EvalOutputs:
    """The named sets of Definitions 13-17 for one changeset."""

    r: TripleStore  # interesting removed
    r_i: TripleStore  # potentially interesting removed
    r_prime: TripleStore  # τ triples that become potentially interesting
    a: TripleStore  # interesting added (incl. τ completions)
    a_i: TripleStore  # potentially interesting added
    overflow: jax.Array


@dataclasses.dataclass(frozen=True)
class StepCapacities:
    n_removed: int = 1024  # D capacity
    n_added: int = 1024  # A capacity
    tau: int = 4096
    rho: int = 4096
    pulls: int = 2048
    fanout: int = 4
    # §Perf HC-C: candidate-dedup probe pool cap (0 = paper-faithful naive)
    dedup_candidates: int = 0
    # re-jit headroom: signature tables sized to headroom x dictionary size
    id_headroom: int = 4

    @property
    def n_i(self) -> int:  # I = A ∪ ρ
        return self.n_added + self.rho

    def doubled(self) -> "StepCapacities":
        return dataclasses.replace(
            self,
            n_removed=self.n_removed * 2,
            n_added=self.n_added * 2,
            tau=self.tau * 2,
            rho=self.rho * 2,
            pulls=self.pulls * 2,
            dedup_candidates=self.dedup_candidates * 2,
        )


def combine_side_results(
    d_res: SideResult,
    a_res: SideResult,
    tau: TripleStore,
    rho: TripleStore,
    caps: StepCapacities,
    extra_overflow,
) -> Tuple[TripleStore, TripleStore, EvalOutputs]:
    """Combine the two side evaluations into Δ(τ), Δ(ρ), Υ (Defs 16-18).

    Shared by the single-interest step and the multi-subscriber broker's
    fused step (:mod:`repro.core.broker`) so both paths are the same traced
    computation — the broker's per-subscriber outputs stay bit-identical to
    N independent :func:`make_interest_step` runs by construction.
    """
    a_cap = caps.n_i + caps.pulls
    r, r_i, r_prime = d_res.interesting, d_res.potential, d_res.pulls
    a, ovf_a = union(a_res.interesting, a_res.pulls, a_cap)
    a_i = a_res.potential

    # Υ (Def 18): target first removes r ∪ r', then adds a
    tau1 = difference(difference(tau, r), r_prime)
    tau1, ovf_t = union(tau1, a, caps.tau)

    # ρ' = ((ρ \ r_i) ∪ a_i ∪ r') \ a   (promotion fix)
    rho1 = difference(rho, r_i)
    rho1, ovf_r1 = union(rho1, a_i, caps.rho)
    rho1, ovf_r2 = union(rho1, r_prime, caps.rho)
    rho1 = difference(rho1, a)

    overflow = (
        d_res.overflow
        | a_res.overflow
        | extra_overflow
        | ovf_a
        | ovf_t
        | ovf_r1
        | ovf_r2
    )
    out = EvalOutputs(
        r=r, r_i=r_i, r_prime=r_prime, a=a, a_i=a_i, overflow=overflow
    )
    return tau1, rho1, out


def make_interest_step(
    plan: CompiledInterest,
    *,
    id_capacity: int,
    caps: StepCapacities,
    matcher=None,
) -> Callable:
    """Jitted (D, A, τ, ρ) -> (τ', ρ', EvalOutputs) for one interest."""
    eval_d = make_side_evaluator(
        plan,
        id_capacity=id_capacity,
        fanout=caps.fanout,
        out_capacity=caps.n_removed,
        pull_capacity=caps.pulls,
        matcher=matcher,
        dedup_candidates=caps.dedup_candidates,
    )
    eval_a = make_side_evaluator(
        plan,
        id_capacity=id_capacity,
        fanout=caps.fanout,
        out_capacity=caps.n_i,
        pull_capacity=caps.pulls,
        matcher=matcher,
        dedup_candidates=caps.dedup_candidates,
    )
    @jax.jit
    def step(
        d_set: TripleStore,
        a_set: TripleStore,
        tau: TripleStore,
        rho: TripleStore,
    ):
        tgt = build_index(tau)
        d_res = eval_d(d_set, tgt)
        i_set, ovf_i = union(a_set, rho, caps.n_i)
        a_res = eval_a(i_set, tgt)
        return combine_side_results(d_res, a_res, tau, rho, caps, ovf_i)

    return step


@dataclasses.dataclass
class ChangesetStats:
    changeset_id: int
    total_removed: int
    total_added: int
    interesting_removed: int
    interesting_added: int
    potential_size: int
    target_size: int
    elapsed_s: float


class InterestSubscription:
    """One registered interest: its plan, τ, ρ, and jitted step."""

    def __init__(
        self,
        expr: InterestExpr,
        dictionary: Dictionary,
        caps: StepCapacities,
        matcher=None,
    ):
        self.expr = expr
        self.dictionary = dictionary
        self.caps = caps
        self.matcher = matcher
        self.plan = compile_interest(expr, dictionary)
        self.id_capacity = dictionary.id_capacity * caps.id_headroom
        self.tau = empty(caps.tau)
        self.rho = empty(caps.rho)
        self._step = make_interest_step(
            self.plan, id_capacity=self.id_capacity, caps=caps, matcher=matcher
        )

    def _rebuild(self, caps: StepCapacities | None = None):
        if caps is not None:
            self.caps = caps
        # recompile plan so late-registered dictionary constants resolve
        self.plan = compile_interest(self.expr, self.dictionary)
        self.id_capacity = self.dictionary.id_capacity * self.caps.id_headroom
        self._step = make_interest_step(
            self.plan,
            id_capacity=self.id_capacity,
            caps=self.caps,
            matcher=self.matcher,
        )
        # re-home stores into (possibly) larger capacities
        self.tau, _ = union(empty(self.caps.tau), self.tau, self.caps.tau)
        self.rho, _ = union(empty(self.caps.rho), self.rho, self.caps.rho)

    def init_target(self, triples: np.ndarray):
        """Load the initial RDFSlice-style subset into τ (paper §2)."""
        while True:
            store, overflow = from_array(
                jnp.asarray(triples, jnp.int32), self.caps.tau
            )
            if not bool(overflow):
                self.tau = store
                return
            self._rebuild(self.caps.doubled())

    def apply(self, d_np: np.ndarray, a_np: np.ndarray) -> EvalOutputs:
        if self.dictionary.id_capacity > self.id_capacity:
            self._rebuild()
        while True:
            caps = self.caps
            if d_np.shape[0] > caps.n_removed or a_np.shape[0] > caps.n_added:
                self._rebuild(caps.doubled())
                continue
            d_store, _ = from_array(jnp.asarray(d_np, jnp.int32), caps.n_removed)
            a_store, _ = from_array(jnp.asarray(a_np, jnp.int32), caps.n_added)
            tau1, rho1, out = self._step(d_store, a_store, self.tau, self.rho)
            if bool(out.overflow):
                self._rebuild(caps.doubled())
                continue
            self.tau, self.rho = tau1, rho1
            return out


class IrapEngine:
    """Host orchestrator: Interest Manager + Changeset Manager + Evaluator.

    Mirrors the iRap architecture (paper §3): interests are registered, then
    changesets stream through ``process_changeset`` and every subscription's
    τ / ρ stores are updated; per-changeset stats are collected.
    """

    def __init__(self, dictionary: Dictionary | None = None):
        # NB: `dictionary or Dictionary()` would discard an *empty* dict
        # (Dictionary defines __len__), silently splitting the id space.
        self.dictionary = dictionary if dictionary is not None else Dictionary()
        self.subs: List[InterestSubscription] = []
        self.stats: List[ChangesetStats] = []
        self._counter = 0

    def register_interest(
        self,
        expr: InterestExpr,
        caps: StepCapacities = StepCapacities(),
        initial_target: np.ndarray | None = None,
        matcher=None,
    ) -> InterestSubscription:
        sub = InterestSubscription(expr, self.dictionary, caps, matcher=matcher)
        if initial_target is not None and initial_target.size:
            sub.init_target(initial_target)
        self.subs.append(sub)
        return sub

    def process_changeset(
        self, removed: np.ndarray, added: np.ndarray
    ) -> List[ChangesetStats]:
        self._counter += 1
        out_stats = []
        for sub in self.subs:
            t0 = time.perf_counter()
            out = sub.apply(removed, added)
            jax.block_until_ready(sub.tau.spo)
            elapsed = time.perf_counter() - t0
            st = ChangesetStats(
                changeset_id=self._counter,
                total_removed=int(removed.shape[0]),
                total_added=int(added.shape[0]),
                interesting_removed=int(out.r.n),
                interesting_added=int(out.a.n),
                potential_size=int(sub.rho.n),
                target_size=int(sub.tau.n),
                elapsed_s=elapsed,
            )
            out_stats.append(st)
            self.stats.append(st)
        return out_stats
