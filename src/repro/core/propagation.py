"""Interest evaluation combination and update propagation (Defs 6, 13-18).

``make_interest_step`` builds the fully jitted per-changeset step for one
interest expression:

    d(i, D)        -> <r, r_i, r'>          (Def 13, over deleted triples)
    α(i, A ∪ ρ)    -> <a, a_i, a'>          (Def 14, over added ∪ potential)
    Δ(τ) = <r ∪ r', a>                      (Def 16)
    Δ(ρ) = <r_i, a_i ∪ r'>                  (Def 17)
    Υ: τ' = (τ \\ (r ∪ r')) ∪ a             (Def 18)
       ρ' = ((ρ \\ r_i) ∪ a_i ∪ r') \\ a    (Def 17 + promotion fix, DESIGN §1)

The host-side :class:`IrapEngine` owns the capacities, re-jits on overflow
(store growth) or dictionary growth, and exposes per-changeset statistics —
the production control loop around the pure functional core.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dictionary import Dictionary
from .evaluation import SideResult, TripleIndex, build_index, make_side_evaluator
from .interest import (
    CompiledInterest,
    InterestExpr,
    compile_interest,
    next_pow2,
)
from .triples import (
    PAD,
    TripleStore,
    difference,
    empty,
    from_array,
    member,
    rehome,
    to_numpy,
    union,
)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["r", "r_i", "r_prime", "a", "a_i", "overflow"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class EvalOutputs:
    """The named sets of Definitions 13-17 for one changeset."""

    r: TripleStore  # interesting removed
    r_i: TripleStore  # potentially interesting removed
    r_prime: TripleStore  # τ triples that become potentially interesting
    a: TripleStore  # interesting added (incl. τ completions)
    a_i: TripleStore  # potentially interesting added
    overflow: jax.Array


@dataclasses.dataclass(frozen=True)
class StepCapacities:
    n_removed: int = 1024  # D capacity
    n_added: int = 1024  # A capacity
    tau: int = 4096
    rho: int = 4096
    pulls: int = 2048
    fanout: int = 4
    # §Perf HC-C: candidate-dedup probe pool cap (0 = paper-faithful naive)
    dedup_candidates: int = 0
    # re-jit headroom: signature tables sized to headroom x dictionary size
    id_headroom: int = 4

    @property
    def n_i(self) -> int:  # I = A ∪ ρ
        return self.n_added + self.rho

    def doubled(self) -> "StepCapacities":
        return dataclasses.replace(
            self,
            n_removed=self.n_removed * 2,
            n_added=self.n_added * 2,
            tau=self.tau * 2,
            rho=self.rho * 2,
            pulls=self.pulls * 2,
            dedup_candidates=self.dedup_candidates * 2,
        )


def combine_side_results(
    d_res: SideResult,
    a_res: SideResult,
    tau: TripleStore,
    rho: TripleStore,
    caps: StepCapacities,
    extra_overflow,
) -> Tuple[TripleStore, TripleStore, EvalOutputs]:
    """Combine the two side evaluations into Δ(τ), Δ(ρ), Υ (Defs 16-18).

    Shared by the single-interest step and the multi-subscriber broker's
    fused step (:mod:`repro.core.broker`) so both paths are the same traced
    computation — the broker's per-subscriber outputs stay bit-identical to
    N independent :func:`make_interest_step` runs by construction.
    """
    a_cap = caps.n_i + caps.pulls
    r, r_i, r_prime = d_res.interesting, d_res.potential, d_res.pulls
    a, ovf_a = union(a_res.interesting, a_res.pulls, a_cap)
    a_i = a_res.potential

    # Υ (Def 18): target first removes r ∪ r', then adds a
    tau1 = difference(difference(tau, r), r_prime)
    tau1, ovf_t = union(tau1, a, caps.tau)

    # ρ' = ((ρ \ r_i) ∪ a_i ∪ r') \ a   (promotion fix)
    rho1 = difference(rho, r_i)
    rho1, ovf_r1 = union(rho1, a_i, caps.rho)
    rho1, ovf_r2 = union(rho1, r_prime, caps.rho)
    rho1 = difference(rho1, a)

    overflow = (
        d_res.overflow
        | a_res.overflow
        | extra_overflow
        | ovf_a
        | ovf_t
        | ovf_r1
        | ovf_r2
    )
    out = EvalOutputs(
        r=r, r_i=r_i, r_prime=r_prime, a=a, a_i=a_i, overflow=overflow
    )
    return tau1, rho1, out


def compose_changesets(
    d1: TripleStore,
    a1: TripleStore,
    d2: TripleStore,
    a2: TripleStore,
    capacity: int,
) -> Tuple[TripleStore, TripleStore, jax.Array]:
    """Sequential composition of two changesets under Definition 6.

    Applying ``<D1, A1>`` then ``<D2, A2>`` to any store equals applying the
    single composed changeset ``<D1 ∪ D2, (A1 \\ D2) ∪ A2>`` (delete-first
    ordering makes late adds win over early deletes and late deletes cancel
    early adds). The broker's push scheduler uses this to accumulate pending
    deltas host-side for slow-cadence subscribers, so a policy firing after k
    changesets routes **one** batched evaluation through the fused pass.

    Returns ``(d, a, overflowed)`` at the given output capacity.
    """
    d, ovf_d = union(d1, d2, capacity)
    a, ovf_a = union(difference(a1, d2), a2, capacity)
    return d, a, ovf_d | ovf_a


@dataclasses.dataclass(frozen=True)
class FrontierChain:
    """Delta-encoded view of the D sides of several overlapping frontiers.

    Flush frontiers overlap by construction: every live
    :class:`ChangesetBatch` composes a *suffix* of the changeset stream, so
    a row deleted once appears in the composed D of every frontier whose
    suffix covers it. Evaluating each frontier's D independently therefore
    re-matches the shared rows once per frontier. The chain factors that
    redundancy out into

    ``union``
        the lex-sorted store of the **distinct** D rows across all chained
        frontiers (under Definition 6 the D sides compose by pure union, so
        the union of a set of suffix-frontiers *is* the oldest frontier's
        composed D — the chain re-homes it, never re-sorts);

    ``seg``
        int32 per-row membership bitmap over the union rows: bit ``f`` set
        iff union row ``i`` is in frontier ``f``'s composed D. Membership
        is established by per-frontier binary-search probes of the union
        rows against each frontier's own store — **not** by a prefix-OR
        over the chain: the A sides compose non-monotonically (a row
        added, removed, then re-added flips membership between frontiers),
        so masks-by-probe is the primitive that stays correct for any
        store handed in, and ``covered`` proves the D-side containment
        instead of assuming it.

    ``covered``
        host bool: True iff every chained frontier's store is fully
        contained in the union (``|union ∩ D_f| == |D_f|`` for all f).
        The broker falls back to the stacked per-frontier pass when this
        fails, so a chain can never silently drop rows.

    One segmented bank-match pass over ``union``
    (:func:`repro.kernels.ops.pattern_bitmask_words_segmented`) then yields
    every frontier's match words — each distinct row is matched exactly
    once, and rows outside a frontier carry zero words, which the
    evaluator's zero-bits discipline turns into "contributes no candidates,
    no signatures, no outputs".
    """

    union: TripleStore  # distinct D rows across the chained frontiers
    seg: jax.Array  # int32[cap] membership bitmap (bit f = frontier f)
    covered: bool  # every frontier's rows found in the union
    n_frontiers: int


@jax.jit
def _chain_membership(
    union: TripleStore, stores: Tuple[TripleStore, ...]
) -> Tuple[jax.Array, jax.Array]:
    """(seg bitmap over union rows, all-frontiers-covered flag)."""
    valid = union.spo[:, 0] != PAD
    seg = jnp.zeros((union.spo.shape[0],), jnp.int32)
    covered = jnp.ones((), bool)
    for f, st in enumerate(stores):
        m = member(st, union.spo) & valid
        seg = seg | (m.astype(jnp.int32) << f)
        covered = covered & (jnp.sum(m, dtype=jnp.int32) == st.n)
    return seg, covered


def build_frontier_chain(
    d_stores: Sequence[TripleStore], base: int, capacity: int
) -> FrontierChain:
    """Chain the D sides of the fired frontiers for one segmented pass.

    ``d_stores`` are the frontiers' composed device stores (any
    capacities, any order — index ``f`` becomes membership bit ``f``);
    ``base`` names the frontier whose store is the distinct-row union
    (the oldest fired frontier under Definition 6 suffix composition).
    The union re-homes to ``capacity`` (pad/slice, never re-sort; the
    caller's capacity guard ensures the base rows fit) and membership is
    probed per frontier, so the result is correct — or reports
    ``covered=False`` — even for stores that violate the suffix-nesting
    assumption. Syncs one device bool per call (at fire points only,
    matching :meth:`ChangesetBatch.row_bounds` discipline).
    """
    union = rehome(d_stores[base], capacity)
    # re-home every store to the flush capacity so the jitted membership
    # pass sees ONE shape signature per (capacity, n_frontiers) — batch
    # buckets vary per round and would otherwise retrace every flush
    homed = tuple(rehome(st, capacity) for st in d_stores)
    seg, covered = _chain_membership(union, homed)
    return FrontierChain(
        union=union,
        seg=seg,
        covered=bool(covered),
        n_frontiers=len(d_stores),
    )


@dataclasses.dataclass
class ChangesetBatch:
    """Host-managed accumulator of composed, not-yet-delivered changesets
    (the composition itself runs through the device triple-set algebra).

    One batch exists per distinct consumption frontier (`first_id`): every
    subscriber whose push policy has deferred the same suffix of the stream
    shares one batch, so accumulation cost scales with the number of distinct
    cadences, not subscribers. Capacities double transparently on overflow
    and *decay* back down at drain points: a long-lived slow-cadence
    frontier that once absorbed a burst would otherwise hold its peak pow2
    bucket forever, so the broker calls :meth:`maybe_decay` after each fire
    and the batch re-homes to the smaller bucket once its live rows have
    padded below half the allocation for ``patience`` consecutive checks
    (:func:`repro.core.triples.rehome` makes the shrink a device-side
    slice — no re-sort, no transfer). ``grow_count`` and
    :meth:`maybe_decay`'s return value feed the broker's capacity
    accounting (``BrokerStats.batch_grows`` / ``batch_shrinks``).

    **Device-resident contract.** Once composed (``n_changesets > 1``, or
    after :meth:`device_stores`), the batch owns two lex-sorted, deduped
    device :class:`~repro.core.triples.TripleStore` values at a power-of-two
    ``capacity``; they are immutable between :meth:`extend` calls, and the
    only host state kept alongside is bookkeeping (`first_id`/`last_id`,
    ``n_changesets``) plus the valid-row counts behind :meth:`row_bounds`
    (synced lazily — two device scalars read once per *fire*, never on the
    per-changeset ingest path). A scheduled fire therefore consumes the batch without a
    device→host→device round trip: :meth:`device_stores` hands the sorted
    stores straight to the evaluator, which re-homes them with
    :func:`repro.core.triples.rehome` (pad/slice, never re-sort) only when
    a cohort's padded capacity differs. ``arrays()`` remains the host
    escape hatch for the round-trip baseline path and external consumers.

    **Row provenance across composition.** ``first_id``/``last_id`` name
    the exact changeset suffix a batch has composed, and Definition 6
    composes the D sides by pure union — so when several frontiers fire
    together, the batch with the smallest ``first_id`` provably holds the
    distinct-row union of every fired D side, and each row's provenance
    (which frontiers contain it) is recoverable by a lex probe of its own
    sorted store. :func:`build_frontier_chain` packages exactly that as a
    :class:`FrontierChain` — union store + per-frontier int32 membership
    bitmap + a containment proof — so the flush evaluator can match each
    distinct row once and compose per-frontier bitsets by masking instead
    of re-matching the shared suffix rows once per frontier.
    """

    removed: TripleStore | None  # composed D (device); None while n == 1
    added: TripleStore | None  # composed A (device); None while n == 1
    removed_np: np.ndarray  # raw first changeset (fast path for n == 1)
    added_np: np.ndarray
    n_changesets: int
    first_id: int
    last_id: int
    capacity: int
    # valid rows of the composed stores, synced lazily by row_bounds()
    # (None = stale); raw-row upper bounds while the batch holds one raw
    # changeset
    d_rows: int | None = None
    a_rows: int | None = None
    # capacity lifecycle accounting: pow2 doublings since creation
    grow_count: int = 0
    _decay_streak: int = 0

    @staticmethod
    def fresh(
        removed: np.ndarray, added: np.ndarray, changeset_id: int
    ) -> "ChangesetBatch":
        cap = max(64, int(removed.shape[0]), int(added.shape[0]))
        return ChangesetBatch(
            removed=None,
            added=None,
            # copy: the batch may outlive the caller's (reusable) buffers
            removed_np=np.array(removed, np.int32, copy=True),
            added_np=np.array(added, np.int32, copy=True),
            n_changesets=1,
            first_id=changeset_id,
            last_id=changeset_id,
            capacity=next_pow2(cap),
        )

    def _materialize(self) -> None:
        while True:
            d, ovf_d = from_array(
                jnp.asarray(self.removed_np, jnp.int32), self.capacity
            )
            a, ovf_a = from_array(
                jnp.asarray(self.added_np, jnp.int32), self.capacity
            )
            if not bool(ovf_d | ovf_a):
                self.removed, self.added = d, a
                self.d_rows = self.a_rows = None
                return
            self.capacity *= 2
            self.grow_count += 1

    def extend(
        self, removed: np.ndarray, added: np.ndarray, changeset_id: int
    ) -> None:
        """Fold one more raw changeset into the composed batch."""
        if self.removed is None:
            self._materialize()
        need = max(int(removed.shape[0]), int(added.shape[0]))
        while self.capacity < need:
            self.capacity *= 2
            self.grow_count += 1
        d2, _ = from_array(jnp.asarray(removed, jnp.int32), self.capacity)
        a2, _ = from_array(jnp.asarray(added, jnp.int32), self.capacity)
        while True:
            d, a, overflow = compose_changesets(
                self.removed, self.added, d2, a2, self.capacity
            )
            if not bool(overflow):
                break
            self.capacity *= 2
            self.grow_count += 1
        self.removed, self.added = d, a
        self.d_rows = self.a_rows = None  # synced lazily at fire time
        self.n_changesets += 1
        self.last_id = changeset_id

    def row_bounds(self) -> Tuple[int, int]:
        """(D rows, A rows) of the composed batch, for capacity guards.

        Exact valid-row counts once composed (synced from the device
        scalars on first use after an :meth:`extend`, i.e. once per fire);
        raw-row upper bounds while the batch still holds a single raw
        changeset.
        """
        if self.removed is None:
            return int(self.removed_np.shape[0]), int(self.added_np.shape[0])
        if self.d_rows is None:
            self.d_rows = int(self.removed.n)
            self.a_rows = int(self.added.n)
        return self.d_rows, self.a_rows

    def maybe_decay(self, patience: int = 2, floor: int = 64) -> bool:
        """Re-home to a smaller pow2 bucket after sustained under-fill.

        Called by the broker at drain points (fires / flushes — never on the
        per-changeset ingest path, so no extra device-scalar syncs there).
        When the composed live rows would pad to at most *half* the current
        allocation for ``patience`` consecutive checks, both stores re-home
        to that smaller power-of-two bucket via
        :func:`repro.core.triples.rehome` — a pure pad/slice, so the shrink
        costs no re-sort and no host transfer. A single burst therefore
        never thrashes the capacity down (the streak resets on any
        well-filled check), while a frontier that has genuinely quieted
        releases its peak allocation. Returns True when a shrink happened.
        """
        if self.removed is None:
            return False
        d_rows, a_rows = self.row_bounds()
        want = max(floor, next_pow2(max(d_rows, a_rows, 1)))
        if want > self.capacity // 2:
            self._decay_streak = 0
            return False
        self._decay_streak += 1
        if self._decay_streak < patience:
            return False
        self.removed = rehome(self.removed, want)
        self.added = rehome(self.added, want)
        self.capacity = want
        self._decay_streak = 0
        return True

    def device_stores(self) -> Tuple[TripleStore, TripleStore]:
        """The composed batch as device stores (D, A) — no host transfer
        beyond the one-time upload of a single-changeset batch."""
        if self.removed is None:
            self._materialize()
        return self.removed, self.added

    def arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """The composed batch as dense host arrays (D, A)."""
        if self.removed is None:
            return self.removed_np, self.added_np
        return to_numpy(self.removed), to_numpy(self.added)


def make_interest_step(
    plan: CompiledInterest,
    *,
    id_capacity: int,
    caps: StepCapacities,
    matcher=None,
) -> Callable:
    """Jitted (D, A, τ, ρ) -> (τ', ρ', EvalOutputs) for one interest."""
    eval_d = make_side_evaluator(
        plan,
        id_capacity=id_capacity,
        fanout=caps.fanout,
        out_capacity=caps.n_removed,
        pull_capacity=caps.pulls,
        matcher=matcher,
        dedup_candidates=caps.dedup_candidates,
    )
    eval_a = make_side_evaluator(
        plan,
        id_capacity=id_capacity,
        fanout=caps.fanout,
        out_capacity=caps.n_i,
        pull_capacity=caps.pulls,
        matcher=matcher,
        dedup_candidates=caps.dedup_candidates,
    )
    @jax.jit
    def step(
        d_set: TripleStore,
        a_set: TripleStore,
        tau: TripleStore,
        rho: TripleStore,
    ):
        tgt = build_index(tau)
        d_res = eval_d(d_set, tgt)
        i_set, ovf_i = union(a_set, rho, caps.n_i)
        a_res = eval_a(i_set, tgt)
        return combine_side_results(d_res, a_res, tau, rho, caps, ovf_i)

    return step


@dataclasses.dataclass
class ChangesetStats:
    changeset_id: int
    total_removed: int
    total_added: int
    interesting_removed: int
    interesting_added: int
    potential_size: int
    target_size: int
    elapsed_s: float


class InterestSubscription:
    """One registered interest: its plan, τ, ρ, and jitted step."""

    def __init__(
        self,
        expr: InterestExpr,
        dictionary: Dictionary,
        caps: StepCapacities,
        matcher=None,
    ):
        self.expr = expr
        self.dictionary = dictionary
        self.caps = caps
        self.matcher = matcher
        self.plan = compile_interest(expr, dictionary)
        self.id_capacity = dictionary.id_capacity * caps.id_headroom
        self.tau = empty(caps.tau)
        self.rho = empty(caps.rho)
        self._step = make_interest_step(
            self.plan, id_capacity=self.id_capacity, caps=caps, matcher=matcher
        )

    def _rebuild(self, caps: StepCapacities | None = None):
        if caps is not None:
            self.caps = caps
        # recompile plan so late-registered dictionary constants resolve
        self.plan = compile_interest(self.expr, self.dictionary)
        self.id_capacity = self.dictionary.id_capacity * self.caps.id_headroom
        self._step = make_interest_step(
            self.plan,
            id_capacity=self.id_capacity,
            caps=self.caps,
            matcher=self.matcher,
        )
        # re-home stores into (possibly) larger capacities
        self.tau, _ = union(empty(self.caps.tau), self.tau, self.caps.tau)
        self.rho, _ = union(empty(self.caps.rho), self.rho, self.caps.rho)

    def init_target(self, triples: np.ndarray):
        """Load the initial RDFSlice-style subset into τ (paper §2)."""
        while True:
            store, overflow = from_array(
                jnp.asarray(triples, jnp.int32), self.caps.tau
            )
            if not bool(overflow):
                self.tau = store
                return
            self._rebuild(self.caps.doubled())

    def apply(self, d_np: np.ndarray, a_np: np.ndarray) -> EvalOutputs:
        if self.dictionary.id_capacity > self.id_capacity:
            self._rebuild()
        while True:
            caps = self.caps
            if d_np.shape[0] > caps.n_removed or a_np.shape[0] > caps.n_added:
                self._rebuild(caps.doubled())
                continue
            d_store, _ = from_array(jnp.asarray(d_np, jnp.int32), caps.n_removed)
            a_store, _ = from_array(jnp.asarray(a_np, jnp.int32), caps.n_added)
            tau1, rho1, out = self._step(d_store, a_store, self.tau, self.rho)
            if bool(out.overflow):
                self._rebuild(caps.doubled())
                continue
            self.tau, self.rho = tau1, rho1
            return out


class IrapEngine:
    """Host orchestrator: Interest Manager + Changeset Manager + Evaluator.

    Mirrors the iRap architecture (paper §3): interests are registered, then
    changesets stream through ``process_changeset`` and every subscription's
    τ / ρ stores are updated; per-changeset stats are collected.
    """

    def __init__(self, dictionary: Dictionary | None = None):
        # NB: `dictionary or Dictionary()` would discard an *empty* dict
        # (Dictionary defines __len__), silently splitting the id space.
        self.dictionary = dictionary if dictionary is not None else Dictionary()
        self.subs: List[InterestSubscription] = []
        self.stats: List[ChangesetStats] = []
        self._counter = 0

    def register_interest(
        self,
        expr: InterestExpr,
        caps: StepCapacities = StepCapacities(),
        initial_target: np.ndarray | None = None,
        matcher=None,
    ) -> InterestSubscription:
        sub = InterestSubscription(expr, self.dictionary, caps, matcher=matcher)
        if initial_target is not None and initial_target.size:
            sub.init_target(initial_target)
        self.subs.append(sub)
        return sub

    def process_changeset(
        self, removed: np.ndarray, added: np.ndarray
    ) -> List[ChangesetStats]:
        self._counter += 1
        out_stats = []
        for sub in self.subs:
            t0 = time.perf_counter()
            out = sub.apply(removed, added)
            jax.block_until_ready(sub.tau.spo)
            elapsed = time.perf_counter() - t0
            st = ChangesetStats(
                changeset_id=self._counter,
                total_removed=int(removed.shape[0]),
                total_added=int(added.shape[0]),
                interesting_removed=int(out.r.n),
                interesting_added=int(out.a.n),
                potential_size=int(sub.rho.n),
                target_size=int(sub.tau.n),
                elapsed_s=elapsed,
            )
            out_stats.append(st)
            self.stats.append(st)
        return out_stats
