"""Columnar, fixed-capacity RDF triple-set algebra.

The TPU-native replacement for Jena's B-tree triple indexes: a triple store is
a lexicographically sorted ``int32[C, 3]`` array (subject, predicate, object
ids) padded at the tail with ``PAD`` sentinel rows plus a valid-count scalar.
Every operation is fixed-shape and jit-friendly; overflow is reported through
flags so the host runtime can grow a store between steps.

Triple ids produced by :mod:`repro.core.dictionary` are dense and >= 0, so
``PAD = 2**31 - 1`` sorts strictly after every valid row.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD = np.int32(np.iinfo(np.int32).max)
WILDCARD = np.int32(-1)


@partial(jax.tree_util.register_dataclass, data_fields=["spo", "n"], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class TripleStore:
    """A sorted, deduplicated, fixed-capacity set of RDF triples."""

    spo: jax.Array  # int32[C, 3], lex-sorted, PAD rows at the tail
    n: jax.Array  # int32[] number of valid rows

    @property
    def capacity(self) -> int:
        return self.spo.shape[0]

    def valid_mask(self) -> jax.Array:
        return self.spo[:, 0] != PAD


def empty(capacity: int) -> TripleStore:
    return TripleStore(
        spo=jnp.full((capacity, 3), PAD, dtype=jnp.int32),
        n=jnp.zeros((), dtype=jnp.int32),
    )


# ---------------------------------------------------------------------------
# lexicographic helpers (columnar int32 — avoids a global x64 flip)
# ---------------------------------------------------------------------------

def lex_less(a: jax.Array, b: jax.Array) -> jax.Array:
    """Row-wise (s, p, o) < comparison; broadcasts over leading dims."""
    s_lt = a[..., 0] < b[..., 0]
    s_eq = a[..., 0] == b[..., 0]
    p_lt = a[..., 1] < b[..., 1]
    p_eq = a[..., 1] == b[..., 1]
    o_lt = a[..., 2] < b[..., 2]
    return s_lt | (s_eq & (p_lt | (p_eq & o_lt)))


def rows_equal(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.all(a == b, axis=-1)


def lex_sort(spo: jax.Array) -> jax.Array:
    """Return ``spo`` sorted lexicographically by (s, p, o)."""
    perm = jnp.lexsort((spo[:, 2], spo[:, 1], spo[:, 0]))
    return spo[perm]


def _dedup_sorted_mask(spo: jax.Array) -> jax.Array:
    """Keep-mask for the first occurrence of each row in a sorted array."""
    prev = jnp.roll(spo, 1, axis=0)
    first = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), ~rows_equal(spo[1:], prev[1:])]
    )
    return first & (spo[:, 0] != PAD)


def compact(spo: jax.Array, keep: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Stable-partition kept rows to the front; pad the rest. Returns (rows, count)."""
    order = jnp.argsort(jnp.logical_not(keep), stable=True)
    rows = spo[order]
    count = jnp.sum(keep, dtype=jnp.int32)
    idx = jnp.arange(spo.shape[0], dtype=jnp.int32)
    rows = jnp.where((idx < count)[:, None], rows, jnp.full_like(rows, PAD))
    return rows, count


def from_array(spo: jax.Array, capacity: int) -> Tuple[TripleStore, jax.Array]:
    """Build a store from an unsorted (possibly duplicated) triple array.

    Returns (store, overflowed) — ``overflowed`` is True when the distinct
    triples exceed ``capacity`` (the store then holds the first ``capacity``).
    """
    spo = jnp.asarray(spo, dtype=jnp.int32)
    if spo.ndim != 2 or spo.shape[1] != 3:
        raise ValueError(f"expected (N, 3) triples, got {spo.shape}")
    srt = lex_sort(spo)
    keep = _dedup_sorted_mask(srt)
    rows, count = compact(srt, keep)
    c = rows.shape[0]
    if c < capacity:
        rows = jnp.concatenate(
            [rows, jnp.full((capacity - c, 3), PAD, dtype=jnp.int32)], axis=0
        )
    elif c > capacity:
        rows = rows[:capacity]
    overflow = count > capacity
    return TripleStore(spo=rows, n=jnp.minimum(count, capacity)), overflow


def from_numpy(triples: np.ndarray, capacity: int) -> TripleStore:
    store, overflow = from_array(jnp.asarray(triples, dtype=jnp.int32), capacity)
    if bool(overflow):
        raise ValueError(
            f"{triples.shape[0]} distinct triples exceed capacity {capacity}"
        )
    return store


# ---------------------------------------------------------------------------
# binary search over sorted rows
# ---------------------------------------------------------------------------

def searchsorted_rows(sorted_spo: jax.Array, queries: jax.Array, side: str = "left") -> jax.Array:
    """Vectorized lexicographic searchsorted. ``queries``: int32[Q, 3]."""
    c = sorted_spo.shape[0]
    q = queries.shape[0]
    lo = jnp.zeros((q,), dtype=jnp.int32)
    hi = jnp.full((q,), c, dtype=jnp.int32)
    iters = max(1, int(np.ceil(np.log2(c + 1))) + 1)

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        row = jnp.take(sorted_spo, jnp.minimum(mid, c - 1), axis=0)
        if side == "left":
            go_right = lex_less(row, queries)
        else:
            go_right = ~lex_less(queries, row)
        active = lo < hi
        new_lo = jnp.where(active & go_right, mid + 1, lo)
        new_hi = jnp.where(active & ~go_right, mid, hi)
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def member(store: TripleStore, queries: jax.Array) -> jax.Array:
    """Boolean membership of each query row in the store."""
    c = store.capacity
    idx = searchsorted_rows(store.spo, queries, side="left")
    rows = jnp.take(store.spo, jnp.minimum(idx, c - 1), axis=0)
    return (idx < c) & rows_equal(rows, queries)


def prefix_range(store: TripleStore, prefix: jax.Array, depth: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """[start, end) of rows matching the first ``depth`` columns of ``prefix``.

    ``prefix``: int32[Q, 3] (columns past ``depth`` ignored); ``depth``:
    int32[Q] in {1, 2, 3}. Works on any store sorted in the column order the
    prefix refers to.
    """
    neg = jnp.int32(np.iinfo(np.int32).min)
    col = jnp.arange(3, dtype=jnp.int32)[None, :]
    lo_q = jnp.where(col < depth[:, None], prefix, neg)
    hi_q = jnp.where(col < depth[:, None], prefix, PAD)
    start = searchsorted_rows(store.spo, lo_q, side="left")
    end = searchsorted_rows(store.spo, hi_q, side="right")
    return start, end


# ---------------------------------------------------------------------------
# set algebra
# ---------------------------------------------------------------------------

def difference(a: TripleStore, b: TripleStore) -> TripleStore:
    """a \\ b, keeping a's capacity."""
    in_b = member(b, a.spo)
    keep = a.valid_mask() & ~in_b
    rows, count = compact(a.spo, keep)
    return TripleStore(spo=rows, n=count)


def intersection(a: TripleStore, b: TripleStore) -> TripleStore:
    in_b = member(b, a.spo)
    keep = a.valid_mask() & in_b
    rows, count = compact(a.spo, keep)
    return TripleStore(spo=rows, n=count)


def union(a: TripleStore, b: TripleStore, capacity: int | None = None) -> Tuple[TripleStore, jax.Array]:
    """a ∪ b with the given output capacity (defaults to a's). Returns (store, overflowed)."""
    capacity = a.capacity if capacity is None else capacity
    both = jnp.concatenate([a.spo, b.spo], axis=0)
    srt = lex_sort(both)
    keep = _dedup_sorted_mask(srt)
    rows, count = compact(srt, keep)
    overflow = count > capacity
    if rows.shape[0] < capacity:
        rows = jnp.concatenate(
            [rows, jnp.full((capacity - rows.shape[0], 3), PAD, dtype=jnp.int32)],
            axis=0,
        )
    else:
        rows = rows[:capacity]
    return TripleStore(spo=rows, n=jnp.minimum(count, capacity)), overflow


def apply_changeset(store: TripleStore, removed: TripleStore, added: TripleStore) -> Tuple[TripleStore, jax.Array]:
    """υ(V, Δ) = (V \\ D) ∪ A  — Definition 6 (delete-first ordering)."""
    without = difference(store, removed)
    return union(without, added, store.capacity)


def rehome(store: TripleStore, capacity: int) -> TripleStore:
    """Move a store to a new capacity WITHOUT re-sorting or host transfer.

    Valid rows are already lex-sorted at the front with a PAD tail, so
    growing pads more PAD rows and shrinking slices the front. Shrinking
    requires ``store.n <= capacity`` (the broker's host-side capacity guard
    enforces this before any device-resident re-home); rows past the new
    capacity are then all PAD by construction.
    """
    c = store.spo.shape[0]
    if c == capacity:
        return store
    if c < capacity:
        spo = jnp.concatenate(
            [store.spo, jnp.full((capacity - c, 3), PAD, dtype=jnp.int32)],
            axis=0,
        )
    else:
        spo = store.spo[:capacity]
    return TripleStore(spo=spo, n=store.n)


def to_numpy(store: TripleStore) -> np.ndarray:
    spo = np.asarray(store.spo)
    return spo[spo[:, 0] != PAD]


def to_set(store: TripleStore) -> set:
    return {tuple(int(x) for x in row) for row in to_numpy(store)}


# ---------------------------------------------------------------------------
# pattern matching (XLA path; the Pallas kernel lives in repro.kernels)
# ---------------------------------------------------------------------------

def match_bitmask(spo: jax.Array, patterns: jax.Array) -> jax.Array:
    """uint32[N] bitset: bit j set iff row matches patterns[j] (-1 = wildcard).

    Padding rows (s == PAD) match nothing.
    """
    n_pat = patterns.shape[0]
    if n_pat > 32:
        raise ValueError("at most 32 patterns per bitset")
    valid = spo[:, 0] != PAD
    acc = jnp.zeros(spo.shape[0], dtype=jnp.uint32)
    for j in range(n_pat):
        pat = patterns[j]
        m = valid
        for k in range(3):
            m = m & ((pat[k] == WILDCARD) | (spo[:, k] == pat[k]))
        acc = acc | (m.astype(jnp.uint32) << j)
    return acc
