# The dry-run (and ONLY the dry-run) builds the production mesh from 512
# placeholder host devices; jax locks the device count at first init, so this
# must precede every other import — including `from repro...`.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the full-size model + sharding plan,
  2. ``jax.jit(step, in_shardings=..., out_shardings=...).lower(**ShapeDtypeStructs)``,
  3. ``lowered.compile()``  — proving the distribution config is coherent,
  4. records ``compiled.memory_analysis()`` / ``cost_analysis()`` and the
     collective bytes parsed from the HLO (all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute operand sizes),
  5. derives the three roofline terms (EXPERIMENTS.md §Roofline) and writes
     one JSON per cell under experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--force]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.launch import sharding as sh
from repro.launch.hlo import collective_bytes, parse_memory_analysis
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
    n_chips,
)
from repro.launch.steps import (
    abstract_cache,
    abstract_state,
    batch_struct,
    decode_inputs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.models import build_model, cells_for
from repro.models.config import SHAPES
from repro.optim import AdamW

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _lower(cfg, cell, mesh, plan: str):
    """Build model + sharding plan and lower one step program."""
    from repro.models import layers as mlayers

    api = build_model(cfg)
    jax.set_mesh(mesh)
    mlayers.ACT_RULES = sh.activation_rules(cfg, cell, mesh, plan)
    try:
        return _lower_inner(cfg, cell, mesh, plan, api)
    finally:
        mlayers.ACT_RULES = {}


def _lower_inner(cfg, cell, mesh, plan, api):
    if cell.kind == "train":
        opt = AdamW(learning_rate=3e-4, weight_decay=0.1, max_grad_norm=1.0)
        params_s, opt_s = abstract_state(api, opt)
        batch_s = batch_struct(cfg, cell)
        p_spec = sh.param_specs(cfg, mesh, params_s, plan)
        o_spec = sh.opt_specs(p_spec)
        b_spec = sh.batch_specs(cfg, cell, mesh)
        step = make_train_step(api, opt)
        metric_spec = {
            "loss": P(), "grad_norm": P(), "xent": P(), "aux": P(),
        }
        if cfg.family not in ("dense", "moe"):
            metric_spec = {"loss": P(), "grad_norm": P(), "xent": P()}
        lowered = jax.jit(
            step,
            in_shardings=(p_spec, o_spec, b_spec),
            out_shardings=(p_spec, o_spec, metric_spec),
        ).lower(params_s, opt_s, batch_s)
    elif cell.kind == "prefill":
        params_s, _ = abstract_state(api, None)
        batch_s = batch_struct(cfg, cell)
        p_spec = sh.param_specs(cfg, mesh, params_s, plan, serve=True)
        b_spec = sh.batch_specs(cfg, cell, mesh)
        cache_s = abstract_cache(api, cell)
        c_spec = sh.cache_specs(cfg, cell, mesh, cache_s, plan)
        step = make_prefill_step(api, max_seq=cell.seq_len)
        lowered = jax.jit(
            step,
            in_shardings=(p_spec, b_spec),
            out_shardings=(P(), c_spec),
        ).lower(params_s, batch_s)
    else:  # decode
        params_s, _ = abstract_state(api, None)
        p_spec = sh.param_specs(cfg, mesh, params_s, plan, serve=True)
        cache_s = abstract_cache(api, cell)
        c_spec = sh.cache_specs(cfg, cell, mesh, cache_s, plan)
        tok_s, pos_s = decode_inputs(cfg, cell)
        tok_spec = sh.decode_token_spec(cell, mesh)
        step = make_decode_step(api)
        donate = (1,) if plan == "opt" else ()  # §Perf: in-place cache update
        lowered = jax.jit(
            step,
            in_shardings=(p_spec, c_spec, tok_spec, P()),
            out_shardings=(P(), c_spec),
            donate_argnums=donate,
        ).lower(params_s, cache_s, tok_s, pos_s)
    return lowered


def _compile_costs(cfg, cell, mesh, plan):
    """(flops, bytes, collective_bytes) per device for one lowered program."""
    compiled = _lower(cfg, cell, mesh, plan).compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll["total_bytes"],
        "coll_by_op": coll["by_op"],
    }


def probe_cfgs(cfg):
    """(full_group_count, cfg_for_groups(g)) for exact-count probe compiles.

    Probes unroll every layer scan and use single-trip / associative seq
    scans so XLA cost analysis sees every iteration; full-model cost is
    recovered as f(1) + (G-1) * (f(2) - f(1)) — linear because probe g and
    g+1 differ by exactly one structural group.
    """
    import dataclasses as dc

    fam = cfg.family
    if fam == "encdec":
        g_full = cfg.n_layers
        mk = lambda g: dc.replace(cfg, n_layers=g, n_enc_layers=g)
    elif fam == "vlm":
        per = cfg.cross_attn_every
        g_full = cfg.n_layers // per
        mk = lambda g: dc.replace(cfg, n_layers=per * g)
    elif fam == "hybrid":
        per = cfg.shared_attn_every
        g_full = cfg.n_layers // per
        tail = cfg.n_layers - g_full * per
        mk = lambda g: dc.replace(cfg, n_layers=per * g + tail)
    elif cfg.attn_pattern == "local_global":
        per = cfg.global_every
        g_full = cfg.n_layers // per
        tail = cfg.n_layers - g_full * per
        mk = lambda g: dc.replace(cfg, n_layers=per * g + tail)
    else:
        g_full = cfg.n_layers
        mk = lambda g: dc.replace(cfg, n_layers=g)
    return g_full, mk


def probe_corrected_costs(cfg, cell, mesh, plan):
    """Trip-count-exact (flops, bytes, collective) via two unrolled probes."""
    import dataclasses as dc

    from repro.models import model as M
    from repro.models import ssm as SS

    g_full, mk = probe_cfgs(cfg)
    if g_full == 1:
        probes = [1]
    else:
        probes = [1, 2]
    M.SCAN_UNROLL = True
    SS.SCAN_ASSOC = True
    try:
        costs = []
        for g in probes:
            pc = mk(g)
            if pc.family in ("ssm", "hybrid"):
                pc = dc.replace(pc, scan_chunk=max(pc.scan_chunk, 1))
            costs.append(_compile_costs(pc, cell, mesh, plan))
    finally:
        M.SCAN_UNROLL = False
        SS.SCAN_ASSOC = False
    f1 = costs[0]
    f2 = costs[-1]
    out = {}
    for k in ("flops", "bytes", "coll"):
        # clamp: tiny decode programs can fuse non-monotonically across g
        delta = max(f2[k] - f1[k], 0.0)
        out[k] = f1[k] + (g_full - 1) * delta
    ops = set(f1["coll_by_op"]) | set(f2["coll_by_op"])
    out["coll_by_op"] = {
        o: f1["coll_by_op"].get(o, 0.0)
        + (g_full - 1)
        * max(f2["coll_by_op"].get(o, 0.0) - f1["coll_by_op"].get(o, 0.0), 0.0)
        for o in ops
    }
    return out


def lower_cell(arch: str, shape: str, mesh_kind: str, plan: str = "baseline",
               remat: str = "none", probes: bool = True):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    import dataclasses

    if remat != "none":
        cfg = dataclasses.replace(cfg, remat=remat)
    cell = SHAPES[shape]
    if plan == "opt" and cell.kind != "train":
        # serving plan holds weights in bf16 (§Perf HC-B iteration 3)
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = n_chips(mesh)

    t0 = time.time()
    lowered = _lower(cfg, cell, mesh, plan)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))

    # cost_analysis is PER-DEVICE and counts while-loop (scan) bodies once;
    # recover trip-count-exact per-device costs from the unrolled probes
    if probes:
        corr = probe_corrected_costs(cfg, cell, mesh, plan)
        flops_dev, bytes_dev, coll_dev = corr["flops"], corr["bytes"], corr["coll"]
        coll_by_op = corr["coll_by_op"]
    else:
        flops_dev, bytes_dev, coll_dev = flops_raw, bytes_raw, coll["total_bytes"]
        coll_by_op = coll["by_op"]

    # global quantities (x chips) + roofline terms in seconds (per spec:
    # term = global_quantity / (chips * per-chip rate) == per-device / rate)
    flops = flops_dev * chips
    bytes_acc = bytes_dev * chips
    coll_total = coll_dev * chips
    t_compute = flops / (chips * PEAK_FLOPS_BF16)
    t_memory = bytes_acc / (chips * HBM_BW)
    t_coll = coll_total / (chips * ICI_BW)
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    # model flops (6ND train / 2ND inference)
    n_active = cfg.n_active_params
    if cell.kind == "train":
        model_flops = 6.0 * n_active * cell.global_batch * cell.seq_len
    elif cell.kind == "prefill":
        model_flops = 2.0 * n_active * cell.global_batch * cell.seq_len
    else:
        model_flops = 2.0 * n_active * cell.global_batch

    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "plan": plan,
        "remat": remat,
        "chips": chips,
        "kind": cell.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "hlo_flops_scan_raw_per_dev": flops_raw,
        "hlo_bytes_scan_raw_per_dev": bytes_raw,
        "collective_bytes": coll_total,
        "collective_breakdown": coll_by_op,
        "roofline": terms,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / flops) if flops else None,
        "memory_analysis": parse_memory_analysis(mem),
        "n_params": cfg.n_params,
        "n_active_params": n_active,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--plan", default="baseline")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [
            (a, c.name, m)
            for a in ARCH_NAMES
            for c in cells_for(a)
            for m in ("single", "multi")
        ]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape, args.mesh)]

    failures = []
    for arch, shape, mesh_kind in cells:
        tag = f"{arch}__{shape}__{mesh_kind}"
        if args.plan != "baseline" or args.remat != "none":
            tag += f"__{args.plan}__{args.remat}"
        path = out_dir / f"{tag}.json"
        if path.exists() and not args.force:
            print(f"[skip] {tag}")
            continue
        print(f"[cell] {tag} ...", flush=True)
        try:
            rec = lower_cell(arch, shape, mesh_kind, args.plan, args.remat)
            path.write_text(json.dumps(rec, indent=1))
            r = rec["roofline"]
            print(
                f"  ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                f"flops={rec['hlo_flops']:.3e} coll={rec['collective_bytes']:.3e}B "
                f"dom={rec['dominant']} "
                f"t=({r['compute_s']:.4f},{r['memory_s']:.4f},{r['collective_s']:.4f})s",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001 — record and continue
            failures.append(tag)
            print(f"  FAIL {tag}: {e}")
            traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
