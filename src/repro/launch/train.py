"""Production training driver: mesh-aware, config-driven, fault-tolerant.

On the CPU container this runs reduced configs on a 1-device mesh; on a real
pod the same entry point builds the production mesh and the sharding plan of
launch/sharding.py (the dry-run proves those compile at 256/512 chips).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 30
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.data import DBpediaLikeGenerator, GeneratorConfig, ReplicaTokenPipeline, Verbalizer
from repro.core import InterestExpr, IrapEngine, StepCapacities
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import AdamW, cosine_warmup
from repro.runtime import Trainer, TrainerConfig


def build_data(cfg, batch, seq):
    gen = DBpediaLikeGenerator(GeneratorConfig(seed=13))
    gen.initial_dump()
    engine = IrapEngine(gen.dict)
    expr = InterestExpr.parse(
        "g", "t",
        bgp=[("?f", "rdf:type", "dbo:SoccerPlayer"),
             ("?f", "foaf:name", "?n"),
             ("?f", "dbo:team", "?t"),
             ("?t", "rdfs:label", "?tn")],
    )
    sub = engine.register_interest(
        expr,
        StepCapacities(n_removed=1024, n_added=2048, tau=1 << 15,
                       rho=1 << 15, pulls=1 << 15, fanout=8),
        initial_target=gen.slice_for(
            lambda t: t[0].startswith(("dbr:Athlete", "dbr:Team"))),
    )
    verb = Verbalizer(vocab=cfg.vocab, dictionary=gen.dict)
    pipe = ReplicaTokenPipeline(verb, batch_size=batch, seq_len=seq)
    pipe.refresh(sub.tau)

    def it():
        n = 0
        while True:
            n += 1
            if n % 50 == 0:
                d_np, a_np = gen.changeset()
                sub.apply(d_np, a_np)
                pipe.refresh(sub.tau)
            b = next(pipe)
            if cfg.family == "encdec":
                b["enc_embed"] = np.zeros(
                    (batch, cfg.enc_seq, cfg.d_model), np.float32)
            if cfg.family == "vlm":
                b["img_embed"] = np.zeros(
                    (batch, cfg.n_img_tokens, cfg.d_model), np.float32)
            yield b

    return it()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/irap_launch_train")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = build_model(cfg)
    opt = AdamW(learning_rate=cosine_warmup(1e-3, 10, args.steps),
                weight_decay=0.01, max_grad_norm=1.0)

    def init_state():
        params = api.init(jax.random.key(0))
        return params, opt.init(params)

    data = build_data(cfg, args.batch, args.seq)
    tr = Trainer(
        make_train_step(api, opt), init_state, data,
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=10),
    )
    print(f"arch={cfg.name} params={cfg.n_params/1e6:.2f}M resume_step={tr.step}")
    hist = tr.run(args.steps, inject_failure_at=args.inject_failure_at)
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({np.mean([h['dt'] for h in hist]):.3f} s/step)")


if __name__ == "__main__":
    main()
