"""Sharding plans: parameter / optimizer / batch / cache PartitionSpecs.

Baseline plan (paper-faithful distribution, DESIGN.md §4):
  * dense weights: Megatron TP over "model" x ZeRO-3 FSDP over ("pod","data")
  * MoE experts: EP over ("pod","data"), expert FFN over "model"
  * embeddings: vocab over "model", d_model over FSDP axes
  * batch: DP over ("pod","data"); long-context (B=1) cells shard the
    sequence/state dims instead
  * optimizer state mirrors the param specs 1:1

``plan`` variants ("baseline" | "opt") let the §Perf hillclimb switch
collective layouts without touching model code.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeCell
from .mesh import dp_axes


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return "/".join(out)


def _size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _div(n: int, mesh, axes) -> bool:
    return n % _size(mesh, axes) == 0


def param_specs(cfg: ModelConfig, mesh, params_shape, plan: str = "baseline",
                serve: bool = False):
    """PartitionSpec pytree for params (shapes from jax.eval_shape).

    ``serve`` + plan="opt": dense weights drop the FSDP factor (pure TP,
    replicated over the DP axes) so decode steps stop paying per-token
    weight all-gathers (§Perf finding 2); MoE experts stay EP-sharded
    (statically resident, no gathers).
    """
    ep = dp_axes(mesh)
    fsdp = None if (serve and plan == "opt") else ep
    model = "model"

    def rule(path, leaf):
        ps = _path_str(path)
        nd = leaf.ndim
        key = ps.rsplit("/", 1)[-1]
        shape = leaf.shape

        def lead(base: int):
            return (None,) * (nd - base)

        # embeddings
        if key == "embed":
            return P(model, fsdp)
        if key == "unembed":
            return P(fsdp, model)
        # attention
        if key in ("wq", "wk", "wv") and "attn" in ps or key in ("wq", "wk", "wv") and ("self" in ps or "cross" in ps):
            return P(*lead(2), fsdp, model)
        if key == "wo" and ("attn" in ps or "self" in ps or "cross" in ps):
            return P(*lead(2), model, fsdp)
        # MoE expert stacks: (..., E, D, Fe) / (..., E, Fe, D). EP over the
        # FSDP axes when E divides; otherwise (granite: 40 experts vs 16/32
        # shards — explicit in_shardings cannot pad) fall back to TP-style
        # sharding of the expert matrices with replicated expert dim.
        if cfg.family == "moe" and "mlp" in ps and "shared" not in ps:
            ep_ok = _div(cfg.n_experts, mesh, ep)
            if key in ("wg", "wi"):
                if ep_ok:
                    return P(*lead(3), ep, None, model)
                return P(*lead(3), None, fsdp, model)
            if key == "wo":
                if ep_ok:
                    return P(*lead(3), ep, model, None)
                return P(*lead(3), None, model, fsdp)
            if key == "router":
                return P(*lead(2), fsdp, None)
        # dense MLP (incl. shared expert)
        if key in ("wg", "wi") and nd >= 2:
            return P(*lead(2), fsdp, model)
        if key == "wo" and nd >= 2:
            return P(*lead(2), model, fsdp)
        # mamba projections
        if key == "in_proj":
            return P(*lead(2), fsdp, model)
        if key == "out_proj":
            return P(*lead(2), model, fsdp)
        if key == "x_proj":
            return P(*lead(2), model, None)
        if key == "dt_proj":
            return P(*lead(2), None, model)
        if key == "conv_w":
            return P(*lead(2), None, model)
        if key == "A_log" and nd >= 2 and shape[-1] == cfg.d_state:
            return P(*lead(2), model, None)
        # everything small (norms, biases, gates, scalar stacks): replicated
        return P()

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_specs(param_spec_tree):
    return {
        "m": param_spec_tree,
        "v": param_spec_tree,
        "step": P(),
    }


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh):
    """Specs for the training/prefill batch dict."""
    dp = dp_axes(mesh)
    b = cell.global_batch
    tok_spec = P(dp, None) if _div(b, mesh, dp) else P(None, None)
    specs: Dict[str, Any] = {"tokens": tok_spec, "labels": tok_spec}
    if cfg.family == "encdec":
        specs["enc_embed"] = P(dp if _div(b, mesh, dp) else None, None, None)
    if cfg.family == "vlm":
        specs["img_embed"] = P(dp if _div(b, mesh, dp) else None, None, None)
    if cell.kind == "prefill":
        specs.pop("labels")
    return specs


def cache_specs(cfg: ModelConfig, cell: ShapeCell, mesh, cache_shape,
                plan: str = "baseline"):
    """PartitionSpec pytree for the decode cache (shapes from eval_shape)."""
    dp = dp_axes(mesh)
    model = "model"
    b = cell.global_batch
    batch_ok = _div(b, mesh, dp)

    kv_keys = {
        "k", "v", "gk", "gv", "lk", "lv", "tk", "tv",
        "self_k", "self_v", "cross_k", "cross_v", "shared_k", "shared_v",
    }

    def rule(path, leaf):
        ps = _path_str(path)
        key = ps.rsplit("/", 1)[-1]
        shape = leaf.shape
        nd = leaf.ndim
        spec = [None] * nd
        # locate the batch dim: first dim equal to the cell's global batch
        # (all cache layouts place batch before head dims)
        bi = next((i for i, s in enumerate(shape) if s == b), None)

        if key in kv_keys:
            if bi is None:
                bi = nd - 4  # (…, B, C, K, Dh)
            ci, ki, di = bi + 1, bi + 2, bi + 3
            if batch_ok:
                spec[bi] = dp
            elif _div(shape[ci], mesh, dp):
                spec[ci] = dp  # long-context: shard the sequence dim
            if _div(shape[ki], mesh, model):
                spec[ki] = model
            elif plan != "opt" and _div(shape[di], mesh, model):
                # baseline: Dh-sharded KV (measured: forces per-step cache
                # reshards — the opt plan replicates non-dividing KV heads
                # and spreads the cache over the sequence dim instead)
                spec[di] = model
            if (plan == "opt" and batch_ok and spec[ki] is None
                    and spec[di] is None and _div(shape[ci], mesh, model)):
                spec[ci] = model
            return P(*spec)

        if key == "conv":
            if bi is None:
                bi = nd - 3
            if batch_ok:
                spec[bi] = dp
            if _div(shape[-1], mesh, model):
                spec[-1] = model
            return P(*spec)

        if key == "ssm":
            if bi is None:
                bi = nd - 3 if cfg.ssm_kind == "mamba1" else nd - 4
            if batch_ok:
                spec[bi] = dp
            if cfg.ssm_kind == "mamba1":
                di = bi + 1  # (…, B, Di, Ds)
                if not batch_ok and _div(shape[di], mesh, dp + (model,)):
                    spec[di] = dp + (model,)
                elif _div(shape[di], mesh, model):
                    spec[di] = model
            else:
                hi, pi = bi + 1, bi + 3  # (…, B, H, N, P)
                if not batch_ok and _div(shape[hi], mesh, dp):
                    spec[hi] = dp
                    if _div(shape[pi], mesh, model):
                        spec[pi] = model
                elif _div(shape[hi], mesh, model):
                    spec[hi] = model
            return P(*spec)

        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def decode_token_spec(cell: ShapeCell, mesh):
    dp = dp_axes(mesh)
    return P(dp) if _div(cell.global_batch, mesh, dp) else P(None)


def activation_rules(cfg: ModelConfig, cell: ShapeCell, mesh, plan: str):
    """Activation sharding constraints for the optimized plan.

    The baseline leaves activations to GSPMD propagation, which resolves the
    GQA head split to full replication over "model" (§Perf finding 1); the
    opt plan pins heads (or head_dim when heads don't divide) to "model" and
    batch to the DP axes, and pins the MoE dispatch buffer to (EP, -, TP).
    """
    if plan != "opt":
        return {}
    from jax.sharding import NamedSharding

    dp = dp_axes(mesh)
    b_ok = _div(cell.global_batch, mesh, dp)
    bspec = dp if b_ok else None
    msize = _size(mesh, "model")
    rules = {}

    def heads_spec(n, allow_dh: bool):
        if n % msize == 0:
            return ("model", None)
        # KV heads that don't divide TP are REPLICATED (Megatron GQA
        # duplication) — sharding d_head instead forces per-step cache
        # reshards (§Perf HC-B iteration 2, refuted hypothesis).
        if allow_dh and cfg.d_head % msize == 0:
            return (None, "model")
        return (None, None)

    hq = heads_spec(cfg.n_heads, allow_dh=True)
    hkv = heads_spec(cfg.n_kv_heads, allow_dh=False)
    rules["attn_q"] = P(bspec, None, *hq)
    rules["attn_kv"] = P(bspec, None, *hkv)
    if cfg.family == "moe":
        ep = dp if _div(cfg.n_experts, mesh, dp) else None
        rules["moe_buf"] = P(
            ep, None, "model" if cfg.d_model % msize == 0 else None
        )
    if cfg.ssm_kind:
        di_ok = cfg.d_inner % msize == 0
        rules["ssm_scan"] = P(bspec, None, "model" if di_ok else None, None)
        rules["ssm_scan5"] = P(
            bspec, None, None, "model" if di_ok else None, None
        )
    return {k: NamedSharding(mesh, v) for k, v in rules.items()}


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
