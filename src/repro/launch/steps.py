"""Step builders + ShapeDtypeStruct input specs for every (arch x shape) cell.

``input_specs`` provides weak-type-correct, shardable stand-ins for every
model input (no device allocation) — the dry-run lowers against these.
Modality frontends are stubs per the assignment: whisper gets precomputed
frame embeddings, the vision arch gets precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import ModelApi, ModelConfig, ShapeCell, build_model
from ..optim import AdamW


def make_train_step(api: ModelApi, opt: AdamW) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            api.train_loss, has_aux=True
        )(params, batch)
        new_p, new_s, gn = opt.update(grads, opt_state, params)
        out = {"loss": loss, "grad_norm": gn}
        out.update(metrics)
        return new_p, new_s, out

    return train_step


def make_prefill_step(api: ModelApi, max_seq: int) -> Callable:
    def prefill_step(params, batch):
        return api.prefill(params, dict(batch, max_seq=max_seq))

    return prefill_step


def make_decode_step(api: ModelApi) -> Callable:
    def decode_step(params, cache, tokens, pos):
        return api.decode_step(params, cache, tokens, pos)

    return decode_step


def batch_struct(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    b, s = cell.global_batch, cell.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((b, s), jnp.int32)}
    if cell.kind == "train":
        batch["labels"] = sds((b, s), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_embed"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["img_embed"] = sds((b, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def abstract_state(api: ModelApi, opt: AdamW | None):
    params = jax.eval_shape(lambda: api.init(jax.random.key(0)))
    opt_state = jax.eval_shape(opt.init, params) if opt is not None else None
    return params, opt_state


def abstract_cache(api: ModelApi, cell: ShapeCell):
    return jax.eval_shape(
        lambda: api.init_cache(cell.global_batch, cell.seq_len)
    )


def decode_inputs(cfg: ModelConfig, cell: ShapeCell):
    sds = jax.ShapeDtypeStruct
    return sds((cell.global_batch,), jnp.int32), sds((), jnp.int32)
