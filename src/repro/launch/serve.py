"""Serving driver: batched prefill + decode over any registered arch.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    b, s = args.batch, args.prompt_len
    max_seq = s + args.gen
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "max_seq": max_seq,
    }
    if cfg.family == "encdec":
        batch["enc_embed"] = jnp.zeros((b, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["img_embed"] = jnp.zeros((b, cfg.n_img_tokens, cfg.d_model), jnp.float32)

    t0 = time.perf_counter()
    logits, cache = api.prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: batch={b} prompt={s} in {t_prefill*1e3:.0f} ms")

    decode = jax.jit(api.decode_step)
    tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"decode: {args.gen-1} steps in {dt*1e3:.0f} ms "
          f"({dt/(args.gen-1)*1e3:.1f} ms/token/batch)")
    print("generated token ids (first sequence):", gen[0].tolist())


if __name__ == "__main__":
    main()
