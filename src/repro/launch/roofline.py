"""Roofline summary: read experiments/dryrun/*.json -> markdown tables.

    PYTHONPATH=src python -m repro.launch.roofline [--out experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

EXP = Path(__file__).resolve().parents[3] / "experiments"


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}µ"


def fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(pattern: str = "*.json"):
    recs = []
    for p in sorted((EXP / "dryrun").glob(pattern)):
        recs.append(json.loads(p.read_text()))
    return recs


def note_for(rec) -> str:
    dom = rec["dominant"]
    if dom == "memory_s":
        if rec["arch"].startswith("falcon") or rec["arch"].startswith("zamba"):
            return "scan state materialization; shard d_inner + bf16 scan"
        return "attention/QKV left replicated over model axis; add head-sharding constraints"
    if dom == "collective_s":
        return "FSDP all-gathers + MoE all_to_all; reduce-scatter grads, overlap"
    return "compute-bound: near roofline; tune block shapes"


def table(recs, mesh: str, plan="baseline", remat="none") -> str:
    rows = [r for r in recs if r["mesh"] == mesh and r.get("plan", "baseline") == plan
            and r.get("remat", "none") == remat]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | t_comp | t_mem | t_coll | dominant | HLO FLOPs | "
        "model FLOPs | useful | bytes/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        t = r["roofline"]
        mem = r.get("memory_analysis", {})
        bpd = mem.get("total_bytes_per_device", 0.0)
        useful = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{r['dominant'].replace('_s','')} | {r['hlo_flops']:.2e} | "
            f"{r['model_flops']:.2e} | "
            f"{useful:.2f} | {fmt_b(bpd)} | {note_for(r)} |"
        )
    return "\n".join(out)


def pick_hillclimb(recs):
    singles = [r for r in recs if r["mesh"] == "single"
               and r.get("plan", "baseline") == "baseline"
               and r.get("remat", "none") == "none"]

    def frac(r):
        t = r["roofline"]
        ideal = r["model_flops"] / (r["chips"] * 197e12)
        actual = max(t.values())
        return ideal / max(actual, 1e-12)

    worst = min(singles, key=frac)
    coll = max(singles, key=lambda r: r["roofline"]["collective_s"]
               / max(sum(r["roofline"].values()), 1e-12))
    return worst, coll


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(EXP / "roofline.md"))
    args = ap.parse_args()
    recs = load()
    worst, coll = pick_hillclimb(recs)
    doc = [
        "# Roofline baselines (single-pod 16x16, v5e constants)",
        "",
        table(recs, "single"),
        "",
        "# Multi-pod (2x16x16) compile proof + terms",
        "",
        table(recs, "multi"),
        "",
        f"hillclimb candidates: worst-fraction={worst['arch']}/{worst['shape']}"
        f", most-collective={coll['arch']}/{coll['shape']}",
    ]
    Path(args.out).write_text("\n".join(doc))
    print("\n".join(doc[-1:]))
    print(f"wrote {args.out} ({len(recs)} records)")


if __name__ == "__main__":
    main()
