"""HLO-text analysis: collective-byte accounting + memory analysis parsing.

``cost_analysis`` does not report collective traffic, so we parse the
compiled HLO: build a symbol table of instruction result sizes, then sum the
operand sizes of every collective op (all-gather, all-reduce, reduce-scatter,
all-to-all, collective-permute) — per EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"(pred|[suf]\d+|bf16|f16|c64|c128)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+(\S+)\(")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict:
    """Sum operand sizes of every collective op in the HLO module text."""
    sizes: Dict[str, int] = {}
    coll_lines = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        sizes[name.lstrip("%")] = _type_bytes(type_str)
        opbase = opcode.split(".")[0]
        if opbase.endswith("-start"):
            opbase = opbase[: -len("-start")]
        if opbase in _COLLECTIVES:
            coll_lines.append((opbase, line))

    by_op: Dict[str, int] = {}
    total = 0
    for opbase, line in coll_lines:
        # operand names inside the (...) call args
        call = line.split("(", 1)[1]
        ops = re.findall(r"%?([\w.\-]+)", call)
        byte_sum = sum(sizes.get(o, 0) for o in ops if o in sizes)
        if byte_sum == 0:
            # fall back to the result size (covers fused operand spellings)
            m = _DEF_RE.match(line)
            byte_sum = _type_bytes(m.group(2)) if m else 0
        by_op[opbase] = by_op.get(opbase, 0) + byte_sum
        total += byte_sum
    return {"total_bytes": float(total), "by_op": {k: float(v) for k, v in by_op.items()}}


_MEM_RE = re.compile(r"([\w ]+):\s*([\d.]+)\s*([KMGT]?i?B)", re.IGNORECASE)
_UNIT = {"B": 1, "KB": 1e3, "MB": 1e6, "GB": 1e9, "TB": 1e12,
         "KIB": 2**10, "MIB": 2**20, "GIB": 2**30, "TIB": 2**40}


def parse_memory_analysis(mem) -> Dict:
    """Normalize compiled.memory_analysis() into plain bytes."""
    out: Dict[str, float] = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = float(v)
    if out:
        out["total_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0)
        )
        return out
    # string fallback
    for key, num, unit in _MEM_RE.findall(str(mem)):
        out[key.strip().lower().replace(" ", "_")] = float(num) * _UNIT.get(
            unit.upper(), 1
        )
    return out
