"""Production mesh construction (TPU v5e pods; 256 chips/pod).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
boots with 512 placeholder host devices while tests/benches must see 1.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

CHIPS_PER_POD = 256


def _auto_mesh(shape, axes):
    """jax.make_mesh with AxisType.Auto where supported (jax >= 0.5)."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _auto_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for multi-host-device tests (8 host devices)."""
    return _auto_mesh((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_chips(mesh) -> int:
    return int(mesh.devices.size)
