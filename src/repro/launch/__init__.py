"""Launch layer: production meshes, sharding plans, dry-run, drivers."""
